"""Primal-dual telemetry: realized utility vs the dual objective.

PD-ORS is an online primal-dual algorithm: each admitted job i fixes a
dual payoff variable lambda_i = max(0, u_i - cost_i) (Eq. 10 / Alg. 1),
and the ledger fixes resource prices p_h^r(t) via the exponential
marginal-price function Q_h^r(rho) = L (U^r/L)^(rho / C_h^r)
(Eqs. 12-14). Weak duality makes the dual objective

    D = sum_i lambda_i + sum_{t,h,r} p_h^r(t) * C_h^r

an *online upper bound on the offline-optimal utility*, so with
P = sum of realized admitted utility,

    P  <=  OPT  <=  D        =>   OPT / P  <=  D / P.

``duality_gap = D - P`` and ``empirical_ratio = D / P`` therefore turn
the paper's Theorem-style guarantee into live telemetry: the empirical
ratio is a per-run certificate, always at least as tight as the
worst-case bound max_r(1, ln(U^r/L)) reported by
``PriceTable.competitive_ratio_bound()``.

The tracker is deliberately cheap (a few float adds per offer, price
term evaluated lazily at snapshot time from the cached price matrices)
and rng-free, so it can stay always-on without perturbing decisions.
It is plain-data (deepcopy-safe), which lets ``SimEngine`` checkpoints
carry it — a recovered run reports the same gap as an uninterrupted
one. In the rolling-window simulator the price term is evaluated over
the *live window* (the only slots carrying prices); lambda_i
accumulates across the whole run, and an optional ``window`` keeps a
bounded recent-offer view for rolling gap gauges.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, Optional

import numpy as np

from .metrics import MetricsRegistry, get_registry


class PDGapTracker:
    """Accumulates per-offer primal/dual contributions against a
    ``PriceTable`` (duck-typed: needs ``price_matrix(t)``, ``cluster``
    with ``capacity_matrix`` and ``horizon``, and
    ``competitive_ratio_bound()``)."""

    def __init__(self, prices: Optional[Any] = None,
                 window: Optional[int] = None):
        self.prices = prices
        self.offers = 0
        self.admits = 0
        self.primal = 0.0        # realized admitted utility  sum u_i
        self.dual_payoff = 0.0   # admitted dual payoffs      sum lambda_i
        self._recent = deque(maxlen=window) if window else None

    # ------------------------------------------------------------ feed
    def bind(self, prices: Any) -> None:
        self.prices = prices

    def record_offer(self, admitted: bool, payoff: float,
                     utility: float) -> None:
        self.offers += 1
        if admitted:
            self.admits += 1
            self.primal += float(utility)
            self.dual_payoff += max(0.0, float(payoff))
        if self._recent is not None:
            self._recent.append(
                (float(utility), max(0.0, float(payoff))) if admitted
                else (0.0, 0.0))

    # ------------------------------------------------------------ read
    def dual_price_term(self) -> float:
        """sum_{t,h,r} p_h^r(t) C_h^r over the priced horizon (lazily,
        from the table's cached matrices — never in the offer path)."""
        pt = self.prices
        if pt is None:
            return 0.0
        cluster = pt.cluster
        cap = np.asarray(cluster.capacity_matrix, dtype=float)
        total = 0.0
        for t in range(int(cluster.horizon)):
            total += float(np.sum(np.asarray(pt.price_matrix(t)) * cap))
        return total

    def snapshot(self) -> Dict[str, Any]:
        price_term = self.dual_price_term()
        dual = self.dual_payoff + price_term
        gap = dual - self.primal
        ratio = (dual / self.primal) if self.primal > 0 else None
        bound = None
        if self.prices is not None:
            bound = float(self.prices.competitive_ratio_bound())
        out = {
            "pd_offers": self.offers,
            "pd_admits": self.admits,
            "pd_primal": self.primal,
            "pd_dual": dual,
            "pd_price_term": price_term,
            "duality_gap": gap,
            "empirical_ratio": ratio,
            "ratio_bound": bound,
        }
        if self._recent is not None and self._recent:
            w_primal = sum(u for u, _ in self._recent)
            w_dual = sum(l for _, l in self._recent)
            out["pd_window_primal"] = w_primal
            out["pd_window_dual_payoff"] = w_dual
        return out

    def publish(self, registry: Optional[MetricsRegistry] = None,
                prefix: str = "repro_pd") -> Dict[str, Any]:
        """Set the gap gauges from a fresh snapshot; returns it."""
        reg = registry or get_registry()
        snap = self.snapshot()
        reg.gauge(f"{prefix}_primal",
                  "realized admitted utility").set(snap["pd_primal"])
        reg.gauge(f"{prefix}_dual",
                  "dual objective (payoffs + price term)").set(snap["pd_dual"])
        reg.gauge(f"{prefix}_duality_gap",
                  "dual - primal (weak-duality slack)").set(
                      snap["duality_gap"])
        if snap["empirical_ratio"] is not None:
            reg.gauge(f"{prefix}_empirical_ratio",
                      "dual / primal upper bound on OPT/ALG").set(
                          snap["empirical_ratio"])
        if snap["ratio_bound"] is not None:
            reg.gauge(f"{prefix}_ratio_bound",
                      "worst-case bound max_r(1, ln U^r/L)").set(
                          snap["ratio_bound"])
        return snap
