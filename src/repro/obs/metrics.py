"""Process-wide counter/gauge/histogram registry with Prometheus-style
text exposition.

One named surface replaces the scattered warn-once ``warnings.warn``
calls and ad-hoc ``policy_health`` dicts: rare events (Pallas fallbacks,
replay-budget exhaustions, solver-fault retries) increment counters the
moment they happen; volume stats that live on hot objects
(``TemplateCache.hits``, jit retrace counts, ``SolverFaultInjector``
dispatch tallies, ``ResilientPolicy.health``) are *mirrored* into gauges
at natural sync points (end of an LP batch, engine summary) so the hot
loops stay untouched. Engine-scope gauges are set from state that the
engine checkpoints, which is what makes the registry deterministic under
``SimEngine.recover()`` — a recovered run ends with the same gauge
values as an uninterrupted one.

Instruments are cheap (a float add behind one dict hit) and always on;
``render()`` produces the Prometheus text format, ``snapshot()`` a flat
dict for JSON rows and tests. Instrument catalog: docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import logging
import threading
from typing import Dict, List, Optional, Sequence, Tuple

_log = logging.getLogger("repro.obs")


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (set/inc/dec)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


_DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)


class Histogram:
    """Fixed-bucket histogram (cumulative counts, Prometheus semantics)."""

    __slots__ = ("name", "help", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = _DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +Inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class MetricsRegistry:
    """Named-instrument registry: get-or-create by name, render as
    Prometheus text. Thread-safe registration (instrument updates are
    plain float ops — the GIL is enough for the counters we keep)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = cls(name, help, **kw)
                    self._instruments[name] = inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"instrument {name!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = _DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help, buckets=buckets)

    # ----------------------------------------------------------- export
    def snapshot(self) -> Dict[str, float]:
        """Flat name -> value dict (histograms expose _sum/_count)."""
        out: Dict[str, float] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Histogram):
                out[f"{name}_sum"] = inst.sum
                out[f"{name}_count"] = float(inst.count)
            else:
                out[name] = inst.value  # type: ignore[attr-defined]
        return out

    def render(self) -> str:
        """Prometheus text exposition format."""
        lines: List[str] = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            kind = {"Counter": "counter", "Gauge": "gauge",
                    "Histogram": "histogram"}[type(inst).__name__]
            if inst.help:  # type: ignore[attr-defined]
                lines.append(f"# HELP {name} {inst.help}")  # type: ignore
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(inst, Histogram):
                cum = 0
                for b, c in zip(inst.buckets, inst.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{_fmt(b)}"}} {cum}')
                cum += inst.counts[-1]
                lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{name}_sum {_fmt(inst.sum)}")
                lines.append(f"{name}_count {inst.count}")
            else:
                lines.append(f"{name} {_fmt(inst.value)}")  # type: ignore
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()

    def get(self, name: str) -> Optional[object]:
        return self._instruments.get(name)

    def value(self, name: str, default: float = 0.0) -> float:
        inst = self._instruments.get(name)
        if inst is None:
            return default
        if isinstance(inst, Histogram):
            return inst.sum
        return inst.value  # type: ignore[attr-defined]


_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return _registry


# -------------------------------------------------------------- helpers
_warned: set = set()


def warn_once_event(counter_name: str, key: str, message: str,
                    **fields: object) -> None:
    """Registry-backed replacement for the scattered warn-once paths.

    Always increments ``counter_name``; emits exactly ONE structured log
    record per ``key`` per process (``logging`` WARNING on
    ``repro.obs`` with the fields attached), so a CPU-fallback bench can
    no longer run silent while the log stays readable.
    """
    _registry.counter(counter_name).inc()
    if key not in _warned:
        _warned.add(key)
        _log.warning("%s %s", message,
                     " ".join(f"{k}={v}" for k, v in sorted(fields.items())),
                     extra={"event_key": key, **fields})


def sync_template_cache(cache, prefix: str = "repro_template_cache") -> None:
    """Mirror a ``TemplateCache``'s hit/miss tallies into gauges (called
    at LP-batch sync points, never per lookup)."""
    _registry.gauge(f"{prefix}_hits",
                    "subset-template cache hits").set(cache.hits)
    _registry.gauge(f"{prefix}_misses",
                    "subset-template cache misses").set(cache.misses)
