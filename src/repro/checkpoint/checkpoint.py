"""Pytree checkpointing to .npz (sharding-aware gather on save, re-shard on
restore).  Layout: <dir>/step_<N>.npz + a small JSON manifest with the tree
structure so arbitrary nested dicts round-trip."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _unflatten(flat: Dict[str, Any], structure) -> Any:
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(t)
        return flat[prefix]

    return walk("", structure)


def _structure_of(tree):
    if isinstance(tree, dict):
        return {k: _structure_of(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_structure_of(v) for v in tree]
    return None


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype.isbuiltin != 1:  # ml_dtypes (bf16, fp8, ...): store as f32
            a = a.astype(np.float32)
        arrays[k] = a
    path = os.path.join(directory, f"step_{step:08d}.npz")
    np.savez(path, **arrays)
    manifest = os.path.join(directory, f"step_{step:08d}.json")
    with open(manifest, "w") as f:
        json.dump({"step": step, "structure": _structure_of(tree),
                   "keys": sorted(arrays), "dtypes": dtypes}, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: Optional[int] = None,
                    shardings=None):
    """Restore; if `shardings` (matching pytree of NamedSharding) is given,
    arrays are placed accordingly."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    manifest = os.path.join(directory, f"step_{step:08d}.json")
    with open(manifest) as f:
        meta = json.load(f)
    data = np.load(path)
    import ml_dtypes  # ships with jax

    dtypes = meta.get("dtypes", {})
    flat = {}
    for k in meta["keys"]:
        a = data[k]
        want = dtypes.get(k, str(a.dtype))
        if want != str(a.dtype):
            try:
                a = a.astype(np.dtype(want))
            except TypeError:
                a = a.astype(getattr(ml_dtypes, want))
        flat[k] = a
    tree = _unflatten(flat, meta["structure"])
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step
