"""repro: PD-ORS online scheduling for distributed ML (paper) built as a
production-grade JAX training/serving framework.

Subpackages:
    core        the paper's scheduler (Algorithms 1-4, baselines, theory)
    sim         event-driven rolling-horizon cluster simulator (trace
                replay, job dynamics, unified policy registry)
    models      model zoo for the 10 assigned architectures
    configs     per-architecture configs + input-shape registry
    data/optim/checkpoint/train/serve    training & serving substrates
    parallel    sharding rules, pod-aware collectives
    kernels     Pallas TPU kernels (flash attention, rmsnorm)
    launch      production meshes, multi-pod dry-run, drivers
    roofline    compiled-artifact roofline analysis
"""
__version__ = "1.0.0"
