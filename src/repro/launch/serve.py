"""Serving launcher: batched prefill+decode over a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b \
        --requests 8 --prompt-len 32 --max-new 16
"""
import argparse
import sys
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax

    from ..checkpoint import load_checkpoint
    from ..configs import get_config
    from ..models import build_model
    from ..serve import Request, ServeEngine

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    if args.ckpt_dir:
        params, step = load_checkpoint(args.ckpt_dir)
        print(f"restored checkpoint step {step}")
    else:
        params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         cache_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new, temperature=args.temperature)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.serve(reqs)
    wall = time.time() - t0
    n_tok = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.request_id)[:4]:
        print(f"req {c.request_id}: prefill {c.prefill_ms:.0f}ms "
              f"decode {c.decode_ms:.0f}ms -> {c.tokens[:6]}")
    print(f"{len(done)} requests, {n_tok} tokens, {n_tok / wall:.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
