"""Production meshes (functions only — importing this module never touches
jax device state; the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, model_split: int = 0):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis.

    model_split > 0 re-factorizes the 16-way model axis into
    (model=16//model_split, model2=model_split) over the SAME 256 chips —
    used by the §Perf head-sharding iteration for head counts (40, 25, ...)
    that don't divide 16."""
    if model_split:
        assert 16 % model_split == 0
        if multi_pod:
            shape = (2, 16, 16 // model_split, model_split)
            axes = ("pod", "data", "model", "model2")
        else:
            shape = (16, 16 // model_split, model_split)
            axes = ("data", "model", "model2")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"))
