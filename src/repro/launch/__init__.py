"""Launchers: production meshes, the multi-pod dry-run, and train/serve
drivers.  NOTE: importing ``repro.launch.dryrun`` sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 — never import it
from test or benchmark processes that need the real device count."""
from .mesh import make_host_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
