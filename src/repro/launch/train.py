"""Training launcher.

Two modes:
  * ``--local``   — run real steps on the host devices (reduced config),
                    the CPU/CI path: mesh over available devices.
  * default       — production lowering: build the 16×16 (or 2×16×16)
                    mesh with forced host devices, compile the train step
                    with the full config, and report the roofline terms
                    (the "deploy would look like this" path on a machine
                    without TPUs).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --local \
        --steps 50
"""
import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.local:
        from ..configs import get_config
        from ..configs.base import InputShape
        from ..optim import AdamWConfig
        from ..train import Trainer, TrainerConfig

        cfg = get_config(args.arch, reduced=True)
        shape = InputShape("local", 128, 8, "train")
        tr = Trainer(cfg, shape, TrainerConfig(
            steps=args.steps, log_every=max(args.steps // 10, 1),
            checkpoint_dir=args.ckpt_dir,
            opt=AdamWConfig(lr=args.lr, weight_decay=0.01)))
        hist = tr.run()
        for h in hist:
            print(f"step {h['step']:5d}  loss {h['loss']:.4f}")
        return 0

    # production lowering path — must set device count before jax init,
    # so re-exec through the dryrun module entry point
    from . import dryrun  # noqa: F401  (sets XLA_FLAGS at import)

    r = dryrun.dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod)
    print("lowered + compiled OK; deploy this artifact on the real mesh.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
