import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) and both production meshes,
``lower().compile()`` the appropriate step function against
ShapeDtypeStruct inputs — no allocation — and record:
    * memory_analysis()  (bytes per device: proves it fits)
    * cost_analysis()    (FLOPs / bytes for the roofline)
    * collective bytes parsed from the compiled HLO

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen3-32b --shape train_4k [--multi-pod] [--all] \
        [--fsdp-over-pod] [--out results.json]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, get_config
from ..configs.base import ArchConfig, InputShape
from ..models import (
    build_model,
    decode_window,
    input_specs,
    serve_state_specs,
)
from ..optim import AdamWConfig
from ..parallel import (
    MeshRules,
    batch_shardings,
    param_shardings,
    serve_state_shardings,
)
from ..roofline.analysis import collective_bytes_from_hlo, roofline_report
from ..train import abstract_train_state, make_train_step
from .mesh import make_production_mesh


def _cost_dict(compiled) -> Dict:
    """compiled.cost_analysis() returns a dict on recent jax but a
    one-element list of dicts on older releases; normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _step_and_specs(cfg: ArchConfig, shape: InputShape, rules: MeshRules,
                    opt_cfg: AdamWConfig):
    """Build (fn, arg_specs, in_shardings, out_shardings) for the shape kind."""
    model = build_model(cfg)
    batch_specs = input_specs(cfg, shape)
    b_sh = batch_shardings(rules, batch_specs)

    if shape.kind == "train":
        state_specs = abstract_train_state(model, opt_cfg)
        p_sh = param_shardings(rules, state_specs["params"])
        opt_sh = {
            "m": param_shardings(rules, state_specs["opt"]["m"]),
            "v": param_shardings(rules, state_specs["opt"]["v"]),
            "step": jax.tree.map(
                lambda _: jax.sharding.NamedSharding(
                    rules.mesh, jax.sharding.PartitionSpec()),
                state_specs["opt"]["step"]),
        }
        st_sh = {"params": p_sh, "opt": opt_sh}
        fn = make_train_step(model, opt_cfg)
        return (fn, (state_specs, batch_specs), (st_sh, b_sh),
                (st_sh, None))

    if shape.kind == "prefill":
        p_abs = model.init_abstract()
        p_sh = param_shardings(rules, p_abs)
        cache_len = shape.seq_len

        def prefill_fn(params, batch):
            logits, state = model.prefill(params, batch, cache_len)
            return logits, state

        out_state = jax.eval_shape(prefill_fn, p_abs, batch_specs)[1]
        st_sh = serve_state_shardings(rules, out_state)
        return (prefill_fn, (p_abs, batch_specs), (p_sh, b_sh),
                (None, st_sh))

    # decode
    p_abs = model.init_abstract()
    p_sh = param_shardings(rules, p_abs, serve=True)
    state_specs = serve_state_specs(cfg, shape)
    st_sh = serve_state_shardings(rules, state_specs)
    win = decode_window(cfg, shape)

    def decode_fn(params, tokens, state):
        return model.decode(params, tokens, state, window_override=win)

    return (decode_fn, (p_abs, batch_specs["tokens"], state_specs),
            (p_sh, b_sh["tokens"], st_sh), (None, st_sh))


def _compile_metrics(cfg: ArchConfig, shape: InputShape, mesh, rules,
                     opt_cfg) -> Dict:
    fn, arg_specs, in_sh, out_sh = _step_and_specs(cfg, shape, rules, opt_cfg)
    from ..parallel.context import activation_sharding

    act_axes = rules.batch_axes if shape.kind != "decode" else ()
    with mesh, activation_sharding(mesh, act_axes):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*arg_specs)
        compiled = lowered.compile()
    cost = _cost_dict(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": collective_bytes_from_hlo(compiled.as_text()),
        "compiled": compiled,
    }


def _extrapolate(cfg: ArchConfig, m1: Dict, m2: Dict) -> Dict:
    """XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so scanned layer stacks are under-counted.  Probe-compile the
    same step at L=1 and L=2: body = m2 - m1, base = m1 - body,
    total(L) = base + L*body (per metric, incl. each collective kind)."""
    L = cfg.num_layers
    out = {}
    for key in ("flops", "hlo_bytes"):
        body = max(m2[key] - m1[key], 0.0)
        base = max(m1[key] - body, 0.0)
        out[key] = base + L * body
    coll = {}
    keys = set(m1["collective_bytes"]) | set(m2["collective_bytes"])
    for k in keys:
        a = m1["collective_bytes"].get(k, 0.0)
        b = m2["collective_bytes"].get(k, 0.0)
        body = max(b - a, 0.0)
        base = max(a - body, 0.0)
        coll[k] = base + L * body
    out["collective_bytes"] = coll
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               fsdp_over_pod: bool = False,
               extrapolate: bool = True,
               verbose: bool = True,
               reduced: bool = False,
               mesh_override=None,
               shape_override: Optional[InputShape] = None,
               cfg_override: Optional[ArchConfig] = None,
               tp_over_pod: bool = False,
               pure_fsdp: bool = False,
               act_constraint: bool = True) -> Dict:
    cfg = cfg_override or get_config(arch, reduced=reduced)
    shape = shape_override or SHAPES[shape_name]
    mesh = (mesh_override if mesh_override is not None
            else make_production_mesh(multi_pod=multi_pod))
    rules = MeshRules(mesh, fsdp_over_pod=fsdp_over_pod,
                      tp_over_pod=tp_over_pod, pure_fsdp=pure_fsdp)
    opt_cfg = AdamWConfig()

    t0 = time.time()
    fn, arg_specs, in_sh, out_sh = _step_and_specs(cfg, shape, rules, opt_cfg)
    from ..parallel.context import activation_sharding

    # decode steps skip the residual-stream constraint: pinning a 1-token
    # activation just forces per-layer reshards (§Perf)
    act_axes = (rules.batch_axes
                if (shape.kind != "decode" and act_constraint) else ())
    with mesh, activation_sharding(mesh, act_axes):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll = collective_bytes_from_hlo(compiled.as_text())
    n_dev = mesh.devices.size

    extr = None
    if extrapolate:
        enc = cfg.encoder_layers
        probe1 = dataclasses.replace(cfg, num_layers=1, unroll_layers=True,
                                     encoder_layers=1 if enc else 0)
        probe2 = dataclasses.replace(cfg, num_layers=2, unroll_layers=True,
                                     encoder_layers=2 if enc else 0)
        m1 = _compile_metrics(probe1, shape, mesh, rules, opt_cfg)
        m2 = _compile_metrics(probe2, shape, mesh, rules, opt_cfg)
        extr = _extrapolate(cfg, m1, m2)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "fsdp_over_pod": fsdp_over_pod,
        "devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_raw": float(cost.get("flops", 0.0)),
        "hlo_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_raw": coll,
        "flops": (extr or {}).get("flops", float(cost.get("flops", 0.0))),
        "hlo_bytes": (extr or {}).get(
            "hlo_bytes", float(cost.get("bytes accessed", 0.0))),
        "collective_bytes": (extr or {}).get("collective_bytes", coll),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={result['mesh']} "
              f"compile={t_compile:.1f}s flops={result['flops']:.3e} "
              f"bytes={result['hlo_bytes']:.3e} "
              f"coll={sum(coll.values()):.3e}")
        print(f"  memory: {result['memory']}")
        print(f"  roofline: {roofline_report(cfg, shape, result)}")
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp-over-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(dryrun_one(
                        arch, shape, multi_pod=mp,
                        fsdp_over_pod=args.fsdp_over_pod))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    print(f"\n[dryrun] {len(results)} ok, {len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
