"""AdamW in pure JAX, sharding-friendly (moments inherit param shardings).

Moments are stored in the params' dtype by default (bf16 for >100B configs
so the optimizer state fits HBM — see DESIGN.md §6); ``fp32_moments=True``
upgrades them for small models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    fp32_moments: bool = False


def adamw_init(params, cfg: AdamWConfig):
    def mom(p):
        dt = jnp.float32 if cfg.fp32_moments else p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(mom, params),
        "v": jax.tree.map(mom, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params, grads, state, cfg: AdamWConfig, lr_scale: jnp.ndarray = 1.0
):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
