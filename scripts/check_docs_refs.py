"""Docs-reference check: every repo path mentioned in docs/*.md exists.

Cheap grep-based gate for the equations-to-code map: extracts every
backtick-quoted repo path (``src/...``, ``scripts/...``, ``tests/...``,
``benchmarks/...``, ``docs/...``, ``BENCH_*.json``, top-level ``*.md``)
and every dotted ``repro.foo.bar`` module reference from the markdown
files under docs/ (plus README.md), and fails listing anything that no
longer exists — so module renames cannot silently rot the architecture
docs.

Usage:  python scripts/check_docs_refs.py  [docfile ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

PATH_RE = re.compile(
    r"`((?:src|scripts|tests|benchmarks|examples|docs)/[\w./\-]+"
    r"|BENCH_[\w.]+\.json|[A-Z][\w\-]*\.md)`"
)
MODULE_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def module_exists(dotted: str) -> bool:
    rel = Path("src", *dotted.split("."))
    return (
        (ROOT / rel).with_suffix(".py").exists()
        or (ROOT / rel / "__init__.py").exists()
    )


def check_file(doc: Path) -> list:
    text = doc.read_text()
    missing = []
    for m in PATH_RE.finditer(text):
        ref = m.group(1)
        if not (ROOT / ref).exists():
            missing.append((doc.name, ref))
    for m in MODULE_RE.finditer(text):
        ref = m.group(1)
        if not module_exists(ref):
            missing.append((doc.name, ref))
    return missing


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    docs = [Path(a) for a in args] if args else sorted(
        (ROOT / "docs").glob("*.md")
    ) + [ROOT / "README.md"]
    missing = []
    checked = 0
    for doc in docs:
        if not doc.exists():
            missing.append(("<cli>", str(doc)))
            continue
        checked += 1
        missing.extend(check_file(doc))
    for doc, ref in missing:
        print(f"check_docs_refs: {doc}: missing reference {ref!r}")
    print(f"check_docs_refs: {checked} file(s) checked, "
          f"{len(missing)} stale reference(s)")
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
