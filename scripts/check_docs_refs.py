"""Docs-reference check: every repo path mentioned in docs/*.md exists,
and every registered public symbol exists in code AND is documented.

Cheap grep-based gate for the equations-to-code map: extracts every
backtick-quoted repo path (``src/...``, ``scripts/...``, ``tests/...``,
``benchmarks/...``, ``docs/...``, ``BENCH_*.json``, top-level ``*.md``)
and every dotted ``repro.foo.bar`` module reference from the markdown
files under docs/ (plus README.md), and fails listing anything that no
longer exists — so module renames cannot silently rot the architecture
docs.

``PUBLIC_SYMBOLS`` additionally pins the public API surfaces the docs
promise to cover: for each (source file, symbol) entry the symbol must
be defined in that file (a rename fails here) and mentioned in at least
one checked markdown file (dropping its documentation fails here).  Add
an entry for every public symbol a PR introduces.

Usage:  python scripts/check_docs_refs.py  [docfile ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

PATH_RE = re.compile(
    r"`((?:src|scripts|tests|benchmarks|examples|docs)/[\w./\-]+"
    r"|BENCH_[\w.]+\.json|[A-Z][\w\-]*\.md)`"
)
MODULE_RE = re.compile(r"`(repro(?:\.\w+)+)`")

# public API surfaces the docs must keep covering: file -> symbols that
# must be defined there and mentioned in docs/*.md or README.md
PUBLIC_SYMBOLS = {
    "src/repro/core/cover_packing.py": [
        "CoverPackingLP",
        "TemplateCache",
        "detect_cover_packing",
        "solve_cover_packing_batch",
        "solve_lp_batch",
        "subset_template_cache",
    ],
    "src/repro/core/lp.py": [
        "linprog_batch",
        "linprog_batch_built",
        "TableauTemplate",
        "lazy_rhs",
    ],
    "src/repro/core/solve_plan.py": ["SolvePlan", "solve_plans",
                                    "patch"],
    "src/repro/core/subproblem.py": ["SubproblemConfig", "rng_mode",
                                     "lp_solver", "SolverFault",
                                     "SolverTimeout", "lp_fault_hook"],
    "src/repro/core/cluster.py": ["set_capacity_mask",
                                  "machine_overcommitted",
                                  "slot_version", "release_group"],
    "src/repro/core/job.py": ["QualityCurve", "ElasticProfile",
                              "at_level", "marginal_floor",
                              "damper_loss"],
    "src/repro/sim/faults.py": ["FaultPlan", "FaultIncident",
                                "SolverFaultInjector",
                                "merge_event_streams"],
    "src/repro/sim/engine.py": ["LedgerInvariantError", "SimKilled",
                                "checkpoint_every", "refail_rate",
                                "engine_mode", "admission_latency",
                                "reshape_cooldown", "ElasticState"],
    "src/repro/sim/policy.py": ["ResilientPolicy", "use_warm_bundles",
                                "on_reshape"],
    "src/repro/sim/metrics.py": ["samples_trained", "P2Quantile",
                                 "job_done", "job_closed",
                                 "deadline_hit", "slo_hit"],
    "src/repro/sim/events.py": ["pop_slot", "RESHAPE"],
    "src/repro/sim/traces.py": ["elastic_frac", "deadline_frac",
                                "slo_frac"],
    "src/repro/sim/window.py": ["release_many", "holders_at", "regrant"],
    "src/repro/sim/service.py": ["OfferService", "poll", "heartbeat",
                                 "metrics_text", "start_http"],
    "src/repro/backend/__init__.py": ["lp_solver_default"],
    "benchmarks/bench_scheduler.py": ["repeat-best-of", "--profile"],
    "src/repro/obs/trace.py": ["Tracer", "Span", "chrome_trace",
                               "phase_table", "total_self_s", "activate"],
    "src/repro/obs/metrics.py": ["MetricsRegistry", "Counter", "Gauge",
                                 "Histogram", "get_registry",
                                 "warn_once_event", "render", "snapshot"],
    "src/repro/obs/pd_gap.py": ["PDGapTracker", "record_offer",
                                "dual_price_term"],
}


def module_exists(dotted: str) -> bool:
    rel = Path("src", *dotted.split("."))
    return (
        (ROOT / rel).with_suffix(".py").exists()
        or (ROOT / rel / "__init__.py").exists()
    )


def check_file(doc: Path) -> list:
    text = doc.read_text()
    missing = []
    for m in PATH_RE.finditer(text):
        ref = m.group(1)
        if not (ROOT / ref).exists():
            missing.append((doc.name, ref))
    for m in MODULE_RE.finditer(text):
        ref = m.group(1)
        if not module_exists(ref):
            missing.append((doc.name, ref))
    return missing


def check_symbols(docs: list) -> list:
    """(origin, complaint) pairs for PUBLIC_SYMBOLS violations."""
    corpus = "\n".join(d.read_text() for d in docs if d.exists())
    out = []
    for rel, symbols in PUBLIC_SYMBOLS.items():
        path = ROOT / rel
        if not path.exists():
            out.append(("PUBLIC_SYMBOLS", f"{rel} (file gone)"))
            continue
        src = path.read_text()
        for sym in symbols:
            # flags like `repeat-best-of` appear verbatim; identifiers
            # must be defined (def/class/field/assignment)
            ident = re.escape(sym)
            defined = (
                "-" in sym and sym in src
            ) or re.search(
                rf"(?:def {ident}\b|class {ident}\b|^\s*{ident}\s*[:=])",
                src, re.M,
            ) is not None
            if not defined:
                out.append(("PUBLIC_SYMBOLS",
                            f"{rel}: symbol {sym!r} not defined"))
            if sym not in corpus:
                out.append(("PUBLIC_SYMBOLS",
                            f"{rel}: symbol {sym!r} undocumented "
                            "(no mention in docs/ or README)"))
    return out


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    docs = [Path(a) for a in args] if args else sorted(
        (ROOT / "docs").glob("*.md")
    ) + [ROOT / "README.md"]
    missing = []
    checked = 0
    for doc in docs:
        if not doc.exists():
            missing.append(("<cli>", str(doc)))
            continue
        checked += 1
        missing.extend(check_file(doc))
    if not args:      # symbol coverage runs against the full default set
        missing.extend(check_symbols(docs))
    for doc, ref in missing:
        print(f"check_docs_refs: {doc}: missing reference {ref!r}")
    print(f"check_docs_refs: {checked} file(s) checked, "
          f"{len(missing)} stale reference(s)")
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
