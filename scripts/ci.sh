#!/usr/bin/env bash
# Tier-1 CI gate: full test suite + the scheduler-throughput smoke benchmark.
#
# The smoke benchmark runs the vectorized PD-ORS core against the frozen
# pre-PR reference on a tiny grid (< 60 s) and exits nonzero if their
# admission decisions or total utility diverge — catching both perf-path
# regressions and semantic drift without the multi-minute full sweep
# (python -m benchmarks.bench_scheduler for that).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.bench_scheduler --smoke --out BENCH_scheduler_smoke.json
