#!/usr/bin/env bash
# Tier-1 CI gate: full test suite + scheduler-throughput smoke + simulator
# smoke + bench-regression guard.
#
# The scheduler smoke benchmark runs the vectorized PD-ORS core against the
# frozen pre-PR reference on a tiny grid (< 60 s) and exits nonzero if their
# admission decisions or total utility diverge — catching both perf-path
# regressions and semantic drift without the multi-minute full sweep
# (python -m benchmarks.bench_scheduler for that).
#
# The scheduler smoke grid covers BOTH regimes: the online
# many-small-jobs point and a heavy-contention (workload_scale=0.3,
# LP-bound) point exercising the batched solve-plan path end to end.
#
# The sim smoke replays a short google-trace stream (completions, failures/
# preemption, departures) through all four policies via the unified
# registry (python -m benchmarks.bench_sim for the full sweep); the chaos
# smoke leg reruns it under the fault-domain harness (machine crashes,
# stragglers, injected LP faults). The docs
# check fails if docs/*.md reference modules that no longer exist. The jax
# leg reruns the backend parity suite with REPRO_BACKEND=jax as the
# process-wide default (skipped cleanly when jax is not importable — e.g.
# a CPU-only box without the toolchain). Finally the guard fails if the
# fresh pdors smoke jobs/sec drops >30% below the smoke baseline recorded
# in BENCH_scheduler.json at the same backend- and shape-aware grid key
# (a grid edit with no matching baseline fails loudly), or if the
# heavy-contention point's in-process speedup over the frozen core falls
# under 2.5x at the FULL heavy point (25x20x50, best-of-2 — the ratio
# is only stable at scale; the cover/packing exact-replay solver lands
# ~3.5x there on recorded best-of rows, and a broken fast path shows
# up as ~1x; see
# docs/SOLVER.md and docs/BENCHMARKS.md). BENCH_GUARD_SKIP=1 bypasses
# entirely on known-noisy runners.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python scripts/check_docs_refs.py
if python -c "import jax" >/dev/null 2>&1; then
  REPRO_BACKEND=jax python -m pytest tests/test_backend.py -q
else
  echo "ci: jax unavailable — skipping the REPRO_BACKEND=jax smoke leg"
fi
python -m benchmarks.bench_scheduler --smoke --repeat-best-of 2 \
  --out BENCH_scheduler_smoke.json
# traced smoke: the same grid with observability on (REPRO_TRACE=1 +
# --profile). The benchmark exits nonzero on any decision divergence
# from the frozen reference, so this leg asserts the tracer's
# zero-interference contract (instrumented decisions bit-identical) on
# every CI run — see docs/OBSERVABILITY.md
REPRO_TRACE=1 python -m benchmarks.bench_scheduler --smoke --profile \
  --baselines "" --out BENCH_scheduler_trace_smoke.json
python -m benchmarks.bench_sim --smoke --out BENCH_sim_smoke.json
# chaos smoke: the same trace under correlated machine crashes,
# stragglers, and injected LP faults (pdors resilient-wrapped) — every
# policy must finish with the ledger invariant intact (check_ledger is
# always on in the engine; a violation raises LedgerInvariantError)
python -m benchmarks.bench_sim --smoke --faults \
  --out BENCH_sim_chaos_smoke.json
# stream smoke: the scaled-down 100k-job configuration — one long google
# stream through the batched engine (streaming metrics) plus a pdors
# service-latency row through the asyncio OfferService boundary. The
# guard enforces absolute floors on the fresh rows: sustained jobs/sec,
# process peak RSS (the streaming-metrics O(1)-rows contract), and the
# admission-latency p99 SLO (see docs/BENCHMARKS.md)
python -m benchmarks.bench_sim --smoke-scale \
  --out BENCH_sim_stream_smoke.json
python scripts/bench_guard.py BENCH_sim_stream_smoke.json \
  --stream-min-jobs-per-sec 400 --stream-max-rss-mb 1024 \
  --stream-max-p99-ms 2000
# elastic smoke: a reshape storm (SLAQ shrink + adadamp grow triggers,
# deadlines and loss SLOs) replayed per policy; every row must report
# batched-vs-event bit-parity on the elastic trace, reshapes actually
# firing, and the loss-SLO attainment floor (see docs/BENCHMARKS.md)
python -m benchmarks.bench_sim --smoke --elastic \
  --out BENCH_sim_elastic_smoke.json
python scripts/bench_guard.py BENCH_sim_elastic_smoke.json \
  --elastic-require-parity --elastic-min-reshapes 1 \
  --elastic-min-slo-attainment 0.5
python scripts/bench_guard.py BENCH_scheduler_smoke.json BENCH_scheduler.json \
  --max-drop 0.30 --min-speedup 2.5 --min-speedup-scale 0.3 \
  --min-speedup-point 25x20x50
