"""Bench-regression guard: compare a fresh smoke benchmark run against the
recorded baseline rows in BENCH_scheduler.json.

Fails (exit 1) if the fresh pdors smoke jobs/sec drops more than
``--max-drop`` (default 30%) below the recorded baseline at the same
(H, T, num_jobs, workload_scale) grid point. Grid points present in only
one of the two files are reported and skipped, so the guard never
false-fails on a machine that has not recorded a baseline yet. Set
``BENCH_GUARD_SKIP=1`` to bypass entirely (e.g. on known-noisy runners).

Usage:
    python scripts/bench_guard.py BENCH_scheduler_smoke.json \
        BENCH_scheduler.json [--max-drop 0.30] [--policy pdors]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _points(doc: dict, policy: str) -> dict:
    out = {}
    for row in doc.get("rows", []):
        if row.get("policy") != policy:
            continue
        # rows written before the backend axis existed are numpy rows
        key = (row["H"], row["T"], row["num_jobs"],
               row.get("workload_scale"), row.get("backend") or "numpy")
        out[key] = row["jobs_per_sec"]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="just-produced smoke benchmark json")
    ap.add_argument("baseline", help="recorded baseline json")
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="max tolerated fractional jobs/sec drop")
    ap.add_argument("--policy", default="pdors")
    args = ap.parse_args(argv)

    if os.environ.get("BENCH_GUARD_SKIP"):
        print("bench_guard: BENCH_GUARD_SKIP set, skipping")
        return 0
    with open(args.fresh) as f:
        fresh = _points(json.load(f), args.policy)
    with open(args.baseline) as f:
        base = _points(json.load(f), args.policy)

    checked = failed = 0
    for key, fresh_jps in sorted(fresh.items()):
        base_jps = base.get(key)
        if base_jps is None:
            print(f"bench_guard: no baseline for H,T,N,scale,backend={key} "
                  "— skipped")
            continue
        checked += 1
        floor = base_jps * (1.0 - args.max_drop)
        verdict = "OK" if fresh_jps >= floor else "REGRESSION"
        if fresh_jps < floor:
            failed += 1
        print(f"bench_guard: {args.policy} @ {key}: {fresh_jps:.1f} jobs/s "
              f"vs baseline {base_jps:.1f} (floor {floor:.1f}) {verdict}")
    if checked == 0:
        print("bench_guard: no comparable grid points — nothing enforced")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
