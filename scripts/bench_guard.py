"""Bench-regression guard: compare a fresh smoke benchmark run against the
recorded baseline rows in BENCH_scheduler.json.

Fails (exit 1) if the fresh pdors smoke jobs/sec drops more than
``--max-drop`` (default 30%) below the recorded baseline at the same
(H, T, num_jobs, workload_scale, seed, quanta, backend) grid point — the
key is backend-aware AND shape-aware, so numpy and jax rows gate
independently and a grid edit (different quanta, seed, or point) can
never silently reuse a stale baseline row. A fresh grid point with NO
matching baseline row fails loudly by default — record a baseline (or
pass ``--allow-missing-baseline`` for machines that genuinely have none
yet) instead of letting the guard silently enforce nothing.

``--min-speedup X --min-speedup-scale S`` additionally gates the
LP-regime speedup: every fresh row at workload_scale S carrying a
``speedup_vs_reference`` field must report at least X. The ratio is
measured in-process against the frozen core, so it is far less
machine-noise-sensitive than absolute jobs/sec — this is the floor that
keeps the heavy-contention batched-solve-plan speedup from silently
regressing.

Set ``BENCH_GUARD_SKIP=1`` to bypass entirely (e.g. on known-noisy
runners).

Usage:
    python scripts/bench_guard.py BENCH_scheduler_smoke.json \
        BENCH_scheduler.json [--max-drop 0.30] [--policy pdors] \
        [--min-speedup 2.0 --min-speedup-scale 0.3]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _points(doc: dict, policy: str) -> dict:
    out = {}
    for row in doc.get("rows", []):
        if row.get("policy") != policy:
            continue
        # the full shape key: a baseline only gates a fresh row measured
        # at the SAME grid point, seed, and DP granularity (rows written
        # before the backend axis existed are numpy rows; quanta rows
        # predating the field fall back to the file-level meta)
        key = (row["H"], row["T"], row["num_jobs"],
               row.get("workload_scale"), row.get("seed"),
               row.get("quanta") or doc.get("quanta"),
               row.get("backend") or "numpy",
               row.get("faults") or False)
        out[key] = (row["jobs_per_sec"], row.get("speedup_vs_reference"))
    return out


def _check_stream(rows: list, args) -> int:
    """Stream-tier gates: absolute floors/ceilings on fresh bench_sim
    --stream/--smoke-scale rows — no baseline file involved (the floors
    are chosen per-runner in ci.sh). Each requested gate must match at
    least one row; a gate that would silently enforce nothing FAILS."""
    failed = 0
    jps_checked = rss_checked = p99_checked = 0
    for row in rows:
        kind = row.get("kind")
        label = f"{row.get('policy')} [{kind}] jobs={row.get('num_jobs')}"
        if kind == "stream":
            if args.stream_min_jobs_per_sec is not None:
                jps_checked += 1
                jps = row["jobs_per_sec"]
                ok = jps >= args.stream_min_jobs_per_sec
                if not ok:
                    failed += 1
                print(f"bench_guard: {label}: {jps:.1f} jobs/s vs floor "
                      f"{args.stream_min_jobs_per_sec:.1f} "
                      f"{'OK' if ok else 'REGRESSION'}")
            if (args.stream_max_rss_mb is not None
                    and row.get("peak_rss_mb") is not None):
                rss_checked += 1
                rss = row["peak_rss_mb"]
                ok = rss <= args.stream_max_rss_mb
                if not ok:
                    failed += 1
                print(f"bench_guard: {label}: peak RSS {rss:.0f}MB vs "
                      f"ceiling {args.stream_max_rss_mb:.0f}MB "
                      f"{'OK' if ok else 'REGRESSION'}")
        if (args.stream_max_p99_ms is not None
                and row.get("admission_p99_ms") is not None):
            p99_checked += 1
            p99 = row["admission_p99_ms"]
            ok = p99 <= args.stream_max_p99_ms
            if not ok:
                failed += 1
            print(f"bench_guard: {label}: admission p99 {p99:.2f}ms vs "
                  f"ceiling {args.stream_max_p99_ms:.2f}ms "
                  f"{'OK' if ok else 'REGRESSION'}")
    for gate, n, name in (
        (args.stream_min_jobs_per_sec, jps_checked, "jobs/sec floor"),
        (args.stream_max_rss_mb, rss_checked, "peak-RSS ceiling"),
        (args.stream_max_p99_ms, p99_checked, "admission-p99 ceiling"),
    ):
        if gate is not None and n == 0:
            print(f"bench_guard: stream {name} set but NO matching fresh "
                  "row — gate not enforced: FAIL")
            failed += 1
    return 1 if failed else 0


def _check_elastic(rows: list, args) -> int:
    """Elastic-tier gates on fresh bench_sim --elastic rows: batched-vs-
    event bit-parity must hold per row, reshapes must actually fire (a
    storm that never reshapes is a dead trigger, not a pass), and the
    loss-SLO attainment floor holds. Same convention as the stream gates:
    a requested gate matching NO row fails loudly."""
    failed = 0
    par_checked = resh_checked = slo_checked = 0
    for row in rows:
        if row.get("kind") != "elastic":
            continue
        label = f"{row.get('policy')} [elastic] jobs={row.get('num_jobs')}"
        if args.elastic_require_parity:
            par_checked += 1
            ok = bool(row.get("engine_parity"))
            if not ok:
                failed += 1
            print(f"bench_guard: {label}: batched-vs-event parity "
                  f"{'OK' if ok else 'BROKEN: FAIL'}")
        if args.elastic_min_reshapes is not None:
            resh_checked += 1
            n = row.get("reshapes", 0)
            ok = n >= args.elastic_min_reshapes
            if not ok:
                failed += 1
            print(f"bench_guard: {label}: {n} reshapes vs floor "
                  f"{args.elastic_min_reshapes} "
                  f"{'OK' if ok else 'REGRESSION'}")
        if args.elastic_min_slo_attainment is not None:
            slo_checked += 1
            att = row.get("slo_attainment", 0.0)
            ok = att >= args.elastic_min_slo_attainment
            if not ok:
                failed += 1
            print(f"bench_guard: {label}: SLO attainment {att:.2f} vs "
                  f"floor {args.elastic_min_slo_attainment:.2f} "
                  f"{'OK' if ok else 'REGRESSION'}")
    for gate, n, name in (
        (args.elastic_require_parity or None, par_checked, "parity gate"),
        (args.elastic_min_reshapes, resh_checked, "reshape floor"),
        (args.elastic_min_slo_attainment, slo_checked,
         "SLO-attainment floor"),
    ):
        if gate is not None and n == 0:
            print(f"bench_guard: elastic {name} set but NO kind=elastic "
                  "fresh row — gate not enforced: FAIL")
            failed += 1
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="just-produced smoke benchmark json")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="recorded baseline json (unused in stream mode)")
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="max tolerated fractional jobs/sec drop")
    ap.add_argument("--policy", default="pdors")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="min speedup_vs_reference for fresh rows at "
                         "--min-speedup-scale")
    ap.add_argument("--min-speedup-scale", type=float, default=0.3,
                    help="workload_scale the --min-speedup floor applies to")
    ap.add_argument("--min-speedup-point", default=None,
                    help="restrict the --min-speedup gate to one HxTxJOBS "
                         "grid point (e.g. 25x20x50) — the ratio is only "
                         "stable at scale; small points are noise-bound")
    ap.add_argument("--stream-min-jobs-per-sec", type=float, default=None,
                    help="stream mode: min sustained jobs/sec for fresh "
                         "kind=stream rows (bench_sim --stream/"
                         "--smoke-scale output)")
    ap.add_argument("--stream-max-rss-mb", type=float, default=None,
                    help="stream mode: max process peak RSS (MiB) for "
                         "fresh kind=stream rows")
    ap.add_argument("--stream-max-p99-ms", type=float, default=None,
                    help="stream mode: max admission-latency p99 (ms) for "
                         "every fresh row carrying admission_p99_ms "
                         "(stream AND service rows)")
    ap.add_argument("--elastic-require-parity", action="store_true",
                    help="elastic mode: every fresh kind=elastic row must "
                         "report engine_parity=true (batched engine "
                         "bit-identical to the per-event oracle on the "
                         "same reshape storm)")
    ap.add_argument("--elastic-min-reshapes", type=int, default=None,
                    help="elastic mode: min reshape count per fresh "
                         "kind=elastic row (the storm's triggers must "
                         "actually fire)")
    ap.add_argument("--elastic-min-slo-attainment", type=float,
                    default=None,
                    help="elastic mode: min loss-SLO attainment per fresh "
                         "kind=elastic row")
    ap.add_argument("--allow-missing-baseline", action="store_true",
                    help="downgrade a fresh grid point with no baseline "
                         "row from FAIL to a skip notice (for machines "
                         "that have not recorded baselines yet)")
    args = ap.parse_args(argv)

    if os.environ.get("BENCH_GUARD_SKIP"):
        print("bench_guard: BENCH_GUARD_SKIP set, skipping")
        return 0
    stream_gates = (args.stream_min_jobs_per_sec, args.stream_max_rss_mb,
                    args.stream_max_p99_ms)
    elastic_gates = (args.elastic_require_parity or None,
                     args.elastic_min_reshapes,
                     args.elastic_min_slo_attainment)
    if any(g is not None for g in stream_gates + elastic_gates):
        with open(args.fresh) as f:
            rows = json.load(f).get("rows", [])
        rc = 0
        if any(g is not None for g in stream_gates):
            rc |= _check_stream(rows, args)
        if any(g is not None for g in elastic_gates):
            rc |= _check_elastic(rows, args)
        return rc
    if args.baseline is None:
        ap.error("baseline json required outside stream mode")
    with open(args.fresh) as f:
        fresh = _points(json.load(f), args.policy)
    with open(args.baseline) as f:
        base = _points(json.load(f), args.policy)

    checked = spd_checked = failed = 0
    for key, (fresh_jps, fresh_spd) in sorted(fresh.items()):
        hit = base.get(key)
        if hit is None:
            if args.allow_missing_baseline:
                print("bench_guard: no baseline for "
                      f"H,T,N,scale,seed,quanta,backend,faults={key} — skipped "
                      "(--allow-missing-baseline)")
            else:
                print("bench_guard: NO baseline row for "
                      f"H,T,N,scale,seed,quanta,backend,faults={key} — a grid "
                      "edit must re-record its baseline: FAIL")
                failed += 1
        else:
            base_jps = hit[0]
            checked += 1
            floor = base_jps * (1.0 - args.max_drop)
            verdict = "OK" if fresh_jps >= floor else "REGRESSION"
            if fresh_jps < floor:
                failed += 1
            print(f"bench_guard: {args.policy} @ {key}: {fresh_jps:.1f} "
                  f"jobs/s vs baseline {base_jps:.1f} (floor {floor:.1f}) "
                  f"{verdict}")
        point_ok = True
        if args.min_speedup_point is not None:
            point_ok = tuple(
                int(v) for v in args.min_speedup_point.split("x")
            ) == (key[0], key[1], key[2])
        if (args.min_speedup is not None and fresh_spd is not None
                and point_ok and key[3] is not None
                and abs(key[3] - args.min_speedup_scale) < 1e-9):
            spd_checked += 1
            verdict = "OK" if fresh_spd >= args.min_speedup else "REGRESSION"
            if fresh_spd < args.min_speedup:
                failed += 1
            print(f"bench_guard: {args.policy} @ {key}: speedup "
                  f"{fresh_spd:.2f}x vs floor {args.min_speedup:.2f}x "
                  f"{verdict}")
    if checked == 0:
        print("bench_guard: no comparable grid points — nothing enforced")
    if args.min_speedup is not None and spd_checked == 0:
        # the speedup floor must not silently degrade to a no-op (e.g. a
        # --no-reference smoke run records no speedup field at all)
        print(f"bench_guard: --min-speedup set but NO fresh row at "
              f"workload_scale={args.min_speedup_scale} carries "
              "speedup_vs_reference — speedup gate not enforced: FAIL")
        failed += 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
