"""Figs. 12-13: total utility on Google-cluster-trace-like arrivals
(bursty arrival profile, trace job-class mix), vs machines and vs jobs."""
from .common import emit, make_jobs, sweep

POLICIES = ("pdors", "oasis", "fifo", "drf", "dorm")


def run(full: bool = False):
    T = 20
    # vs machines
    I = 30 if full else 20
    hs = [10, 30, 50] if full else [8, 16]
    rows = sweep(
        list(POLICIES), hs,
        lambda h, seed: (make_jobs(I, T, seed, trace=True), h, T),
        seeds=(0, 1),
    )
    emit("fig12_trace_vs_machines", rows, "H")
    # vs jobs
    H = 30 if full else 10
    i_s = [20, 60, 100] if full else [12, 24]
    rows2 = sweep(
        list(POLICIES), i_s,
        lambda i, seed: (make_jobs(i, T, seed, trace=True), H, T),
        seeds=(0, 1),
    )
    emit("fig13_trace_vs_jobs", rows2, "I")
    return rows + rows2


if __name__ == "__main__":
    run()
