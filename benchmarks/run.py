"""Benchmark harness: one function per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` uses paper-scale
sweeps (slow); default sizes finish on one CPU core in ~15 minutes.
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: only the scheduler-throughput smoke "
                         "benchmark (tiny grid, < 60 s)")
    ap.add_argument("--only", default=None,
                    help="comma-separated figure names (e.g. fig6,fig10)")
    args = ap.parse_args()

    if args.smoke:
        from . import bench_scheduler
        sys.exit(bench_scheduler.main(
            ["--smoke", "--out", "BENCH_scheduler_smoke.json"]
        ))

    from . import (
        fig6_machines, fig7_jobs, fig8_oasis, fig9_median_time,
        fig10_competitive, fig11_gdelta, fig12_13_trace, fig14_17_jobmix,
        roofline_table,
    )
    figures = {
        "fig6": fig6_machines.run,
        "fig7": fig7_jobs.run,
        "fig8": fig8_oasis.run,
        "fig9": fig9_median_time.run,
        "fig10": fig10_competitive.run,
        "fig11": fig11_gdelta.run,
        "fig12_13": fig12_13_trace.run,
        "fig14_17": fig14_17_jobmix.run,
        "roofline": roofline_table.run,
    }
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name, fn in figures.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===")
        try:
            if name in ("roofline",):
                fn()
            else:
                fn(full=args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
