"""Fig. 6: total utility vs number of machines (synthetic arrivals).
Paper: T=20, I=50, H in 20..100; scaled here to CPU-budget sizes."""
from .common import emit, make_jobs, sweep

POLICIES = ("pdors", "oasis", "fifo", "drf", "dorm")


def run(full: bool = False):
    T, I = 20, 50 if full else 24
    hs = [20, 40, 60, 80, 100] if full else [8, 16, 24]
    rows = sweep(
        list(POLICIES), hs,
        lambda h, seed: (make_jobs(I, T, seed), h, T),
        seeds=(0, 1),
    )
    emit("fig6_utility_vs_machines", rows, "H")
    # paper's qualitative claim: PD-ORS dominates at every point
    by_x = {}
    for r in rows:
        by_x.setdefault(r["x"], {})[r["policy"]] = r["utility"]
    wins = sum(
        1 for x, d in by_x.items()
        if d["pdors"] >= max(v for k, v in d.items() if k != "pdors") * 0.95
    )
    print(f"fig6_check,0,pdors_wins_at={wins}/{len(by_x)}_points")
    return rows


if __name__ == "__main__":
    run()
