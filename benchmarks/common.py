"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (
    WorkloadConfig,
    make_cluster,
    run_baseline,
    run_oasis,
    run_pdors,
    synthetic_jobs,
    trace_jobs,
)

# benchmark-scale workload defaults: paper ranges with workload scaled so a
# meaningful fraction of jobs is completable within T (see DESIGN.md §9)
BENCH = dict(batch=(50, 200), workload_scale=0.3)


def make_jobs(num_jobs: int, horizon: int, seed: int, trace: bool = False,
              mix=None, workload_scale: float = None):
    kw = dict(BENCH)
    if mix is not None:
        kw["mix"] = mix
    if workload_scale is not None:
        kw["workload_scale"] = workload_scale
    cfg = WorkloadConfig(num_jobs=num_jobs, horizon=horizon, seed=seed, **kw)
    return (trace_jobs if trace else synthetic_jobs)(cfg)


def run_policy(name: str, jobs, num_machines: int, horizon: int,
               seed: int = 0) -> Dict:
    """Run one scheduling policy; returns utility + timing."""
    cluster = make_cluster(num_machines, horizon)
    t0 = time.time()
    if name == "pdors":
        res = run_pdors(jobs, cluster, quanta=horizon, seed=seed)
        util = res.total_utility
        extra = {"admitted": len(res.admitted),
                 "times": res.training_times(horizon)}
    elif name == "oasis":
        res = run_oasis(jobs, cluster, quanta=horizon, seed=seed)
        util = res.total_utility
        extra = {"admitted": len(res.admitted),
                 "times": res.training_times(horizon)}
    else:
        out = run_baseline(name, jobs, cluster, seed=seed)
        util = out.total_utility
        extra = {"admitted": len(out.completions),
                 "times": out.training_times(jobs, horizon)}
    wall = time.time() - t0
    return {"utility": util, "wall_s": wall,
            "us_per_job": wall / max(len(jobs), 1) * 1e6, **extra}


def sweep(policies: List[str], xs: List[int], make_args: Callable,
          seeds=(0, 1)) -> List[Dict]:
    """For each x and policy, average utility over seeds."""
    rows = []
    for x in xs:
        for pol in policies:
            utils, uspj, admitted = [], [], []
            for seed in seeds:
                jobs, H, T = make_args(x, seed)
                r = run_policy(pol, jobs, H, T, seed=seed)
                utils.append(r["utility"])
                uspj.append(r["us_per_job"])
                admitted.append(r["admitted"])
            rows.append({
                "x": x, "policy": pol,
                "utility": float(np.mean(utils)),
                "us_per_job": float(np.mean(uspj)),
                "admitted": float(np.mean(admitted)),
            })
    return rows


def emit(name: str, rows: List[Dict], x_label: str = "x") -> None:
    """CSV lines: name,us_per_call,derived..."""
    for r in rows:
        print(f"{name}[{x_label}={r['x']},{r['policy']}],"
              f"{r['us_per_job']:.0f},"
              f"utility={r['utility']:.1f};admitted={r['admitted']:.1f}")
