"""Figs. 14-17: sensitivity to the job-criticality mix.

Paper: with the (10%, 55%, 35%) mix the PD-ORS-over-OASiS utility gain is
larger than with the trace-realistic (30%, 69%, 1%) mix — fewer
time-critical jobs => less advantage from smart scheduling."""
import numpy as np

from .common import make_jobs, run_policy


def run(full: bool = False):
    T = 20
    H = 20 if full else 10
    I = 40 if full else 24
    gains = {}
    for label, mix in (("crit35", (0.10, 0.55, 0.35)),
                       ("crit1", (0.30, 0.69, 0.01))):
        g = []
        for seed in (0, 1, 2):
            jobs = make_jobs(I, T, seed, mix=mix)
            p = run_policy("pdors", jobs, H, T, seed=seed)["utility"]
            o = run_policy("oasis", jobs, H, T, seed=seed)["utility"]
            g.append(p / max(o, 1e-9))
        gains[label] = float(np.mean(g))
        print(f"fig14_17_jobmix[{label}],0,"
              f"pdors_over_oasis={gains[label]:.3f}")
    print(f"fig14_17_check,0,gain_crit35>{'=' if gains['crit35'] >= gains['crit1'] else '<'}gain_crit1 "
          f"(paper: more critical jobs => larger gain)")
    return gains


if __name__ == "__main__":
    run()
