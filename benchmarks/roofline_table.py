"""Deliverable (g): roofline table over all (arch x shape) baselines.

Reads results/dryrun_baseline.json (written by repro.launch.dryrun --all)
and prints the three terms + dominant bottleneck per pair on the
single-pod mesh, plus MODEL_FLOPS/HLO_FLOPs utilization."""
import json
import os

from repro.configs import SHAPES, get_config
from repro.roofline import roofline_terms

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_baseline.json")


def run(path: str = RESULTS):
    if not os.path.exists(path):
        print("roofline_table,0,SKIPPED (run repro.launch.dryrun --all first)")
        return []
    with open(path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("multi_pod"):
            continue  # roofline table is single-pod (spec)
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        t = roofline_terms(cfg, shape, r)
        rows.append((r["arch"], r["shape"], t))
        print(f"roofline[{r['arch']},{r['shape']}],0,"
              f"compute={t['compute_s']:.3e};memory={t['memory_s']:.3e};"
              f"collective={t['collective_s']:.3e};dominant={t['dominant']};"
              f"useful={t['useful_flops_frac']:.2f}")
    return rows


if __name__ == "__main__":
    run()
