"""Fig. 8: PD-ORS vs OASiS with increasing jobs — the co-location gain.
The paper's claim: the gap widens as the number of jobs increases."""
import numpy as np

from .common import emit, make_jobs, sweep


def run(full: bool = False):
    T = 20
    H = 20 if full else 10
    i_s = [20, 40, 60, 80] if full else [10, 20, 30, 40]
    rows = sweep(
        ["pdors", "oasis"], i_s,
        lambda i, seed: (make_jobs(i, T, seed), H, T),
        seeds=(0, 1, 2),
    )
    emit("fig8_pdors_vs_oasis", rows, "I")
    gains = {}
    for r in rows:
        gains.setdefault(r["x"], {})[r["policy"]] = r["utility"]
    for x, d in sorted(gains.items()):
        g = d["pdors"] / max(d["oasis"], 1e-9)
        print(f"fig8_gain[I={x}],0,pdors_over_oasis={g:.3f}")
    return rows


if __name__ == "__main__":
    run()
