"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
results/dryrun_baseline.json (single source of truth)."""
import json
import os
import sys

from repro.configs import SHAPES, get_config
from repro.roofline import roofline_terms

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_baseline.json")


def fmt_bytes(b):
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def dryrun_section(results):
    lines = [
        "## §Dry-run",
        "",
        "Every (architecture × input shape) pair lowered **and compiled** on",
        "both production meshes — 16×16 `(data, model)` (256 chips) and",
        "2×16×16 `(pod, data, model)` (512 chips).  Columns: compile wall",
        "time, per-device peak bytes from `memory_analysis()`, extrapolated",
        "HLO FLOPs (XLA counts a `lax.scan` body once — see DESIGN.md; the",
        "dry-run probe-compiles L=1/L=2 unrolled variants and extrapolates",
        "`total = base + L·body`), and summed collective bytes from the",
        "compiled HLO.",
        "",
        "| arch | shape | mesh | compile_s | peak/dev | HLO FLOPs | coll bytes |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        peak = r["memory"]["peak_bytes"] / r["devices"]
        coll = sum(v for k, v in r["collective_bytes"].items()
                   if k not in ("cross_pod", "intra_pod"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f} | {fmt_bytes(peak)} | "
            f"{r['flops']:.2e} | {fmt_bytes(coll)} |")
    n_ok = len(results)
    lines += ["", f"**{n_ok}/80 combinations lower and compile.**", ""]
    return "\n".join(lines)


def roofline_section(results):
    lines = [
        "## §Roofline",
        "",
        "Per (arch × shape) on the **single-pod 16×16 mesh** (256 chips).",
        "Terms in seconds/step (per chip): compute = FLOPs/(chips·197e12);",
        "memory = analytic fused HBM-traffic model /(chips·819e9) — the raw",
        "XLA `bytes accessed` (pre-fusion upper bound) is in parentheses;",
        "collective = intra/(chips·50e9) + cross/(chips·6.25e9).",
        "`useful` = MODEL_FLOPS(6·N_active·D or 2·N·D) / extrapolated HLO",
        "FLOPs — recompute (remat) and dispatch waste push it below 1.",
        "",
        "| arch | shape | compute_s | memory_s (upper) | collective_s |"
        " dominant | useful | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        ("compute", "train"): "drop remat to `dots` (recompute is ~25% of FLOPs)",
        ("compute", "prefill"): "flash-attention kernel (fewer softmax passes)",
        ("compute", "decode"): "gather-based MoE dispatch / fewer dead FLOPs",
        ("memory", "train"): "larger per-chip batch raises arithmetic intensity",
        ("memory", "prefill"): "KV-cache in bf16; fuse attention (flash kernel)",
        ("memory", "decode"): "batch more sequences per step to amortize weight reads",
        ("collective", "train"): "hierarchical pod-aware grad sync (§Perf)",
        ("collective", "prefill"): "shard KV on model axis to kill all-gathers",
        ("collective", "decode"): "replicate small params; avoid per-step all-gathers",
    }
    rows = []
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("multi_pod"):
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        t = roofline_terms(cfg, shape, r)
        rows.append((r, t))
        lever = levers.get((t["dominant"], shape.kind), "-")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} ({t['memory_upper_s']:.1e}) | "
            f"{t['collective_s']:.2e} | **{t['dominant']}** | "
            f"{t['useful_flops_frac']:.2f} | {lever} |")
    # summary of dominant terms
    from collections import Counter
    doms = Counter(t["dominant"] for _, t in rows)
    lines += ["", f"Dominant-term census: {dict(doms)}", ""]
    return "\n".join(lines), rows


OPTIMIZED = os.path.join(os.path.dirname(__file__), "..", "results",
                         "dryrun_optimized.json")


def optimized_section(base, opt):
    from collections import Counter

    lines = [
        "### Optimized defaults vs paper-faithful baseline (all 40 pairs)",
        "",
        "The §Perf winners became defaults (one-hot CE, activation pinning,",
        "EP train rules + serve overrides, unsharded-vocab embedding).  Full",
        "re-sweep on the single-pod mesh:",
        "",
        "| arch | shape | collective_s base → opt | dominant base → opt |",
        "|---|---|---|---|",
    ]
    bidx = {(r["arch"], r["shape"]): r for r in base if not r["multi_pod"]}
    doms = Counter()
    gains = []
    for r in sorted(opt, key=lambda r: (r["arch"], r["shape"])):
        if r.get("multi_pod"):
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        b = bidx.get((r["arch"], r["shape"]))
        tb = roofline_terms(cfg, shape, b)
        to = roofline_terms(cfg, shape, r)
        doms[to["dominant"]] += 1
        if tb["collective_s"] > 0:
            gains.append(tb["collective_s"] / max(to["collective_s"], 1e-12))
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{tb['collective_s']:.2e} → {to['collective_s']:.2e} | "
            f"{tb['dominant']} → **{to['dominant']}** |")
    import numpy as np
    improved = sum(1 for g in gains if g > 1.2)
    big = sum(1 for g in gains if g > 2.0)
    lines += [
        "",
        f"Optimized dominant-term census: {dict(doms)}.  Collective term",
        f"improved >1.2× on {improved}/40 pairs (> 2× on {big}; max"
        f" {max(gains):.0f}×) — the rest (decode shapes, SSM archs) were",
        "already at their default-rule optimum; the launcher-level",
        "`pure_fsdp` flag adds a further ~2× on the large dense/MoE train",
        "pairs (recorded per-variant in §Perf below).",
        "",
    ]
    return "\n".join(lines)


def main():
    with open(RESULTS) as f:
        results = json.load(f)
    print(dryrun_section(results))
    print(roofline_section(results)[0])
    if os.path.exists(OPTIMIZED):
        with open(OPTIMIZED) as f:
            opt = json.load(f)
        print(optimized_section(results, opt))


if __name__ == "__main__":
    main()
