"""Fig. 7: total utility vs number of jobs (synthetic arrivals).
Paper: T=20, H=100, I sweep; scaled sizes here."""
from .common import emit, make_jobs, sweep

POLICIES = ("pdors", "oasis", "fifo", "drf", "dorm")


def run(full: bool = False):
    T = 20
    H = 100 if full else 12
    i_s = [20, 40, 60, 80, 100] if full else [10, 20, 30]
    rows = sweep(
        list(POLICIES), i_s,
        lambda i, seed: (make_jobs(i, T, seed), H, T),
        seeds=(0, 1),
    )
    emit("fig7_utility_vs_jobs", rows, "I")
    return rows


if __name__ == "__main__":
    run()
