"""Fig. 11: impact of the pre-rounding gain factor G_delta.

The paper varies G_delta in [0.2, 1.2] and reports the utility performance
ratio (best near G_delta = 1).  We sweep the override and report (a) total
utility and (b) the empirical rounding-cost inflation vs the LP optimum,
which Theorems 3-4 bound by 3 G_delta / delta."""
import numpy as np

from repro.core import SubproblemConfig, make_cluster, run_pdors
from .common import make_jobs


def run(full: bool = False):
    T = 20
    H = 20 if full else 10
    I = 50 if full else 24
    best = None
    utils = {}
    for gd in (0.2, 0.4, 0.6, 0.8, 1.0, 1.2):
        vals = []
        for seed in (0, 1, 2, 3):
            jobs = make_jobs(I, T, seed)
            cfg = SubproblemConfig(g_delta=gd)
            res = run_pdors(jobs, make_cluster(H, T), cfg=cfg, quanta=T,
                            seed=seed)
            vals.append(res.total_utility)
        utils[gd] = float(np.mean(vals))
        print(f"fig11_gdelta[G={gd}],0,utility={utils[gd]:.1f}")
    best = max(utils, key=utils.get)
    near_one_ok = utils[1.0] >= 0.95 * utils[best]
    print(f"fig11_best,0,G_delta={best};u(1.0)_within_5pct_of_max={near_one_ok} "
          f"(paper: best near 1.0; we observe a flat plateau)")
    return utils


if __name__ == "__main__":
    run()
