"""Event-driven simulator benchmark: policies x trace presets x cluster
sizes, every policy running under the unified registry + engine accounting.

For each grid point a long trace (arrivals, completions, failures ->
preemption, patience departures) is replayed through each policy via
``repro.sim``; the per-policy record carries scheduling quality (JCT
p50/p95, admission/completion rate, mean utilization, realized utility)
and engine throughput (jobs/sec of wall-clock simulation). Results land in
``BENCH_sim.json``.

The default grid replays a >= 500-job Google-trace-like stream plus a
Philly-style heavy-tail stream at two cluster sizes. ``--smoke`` is the
CI-sized variant (< 60 s). ``pdors_ref`` (the frozen scalar core behind
the same adapter protocol) is off by default — it is ~20x slower at equal
decisions; enable with ``--with-reference`` to time it.

``--backend jax`` runs the grid on the device-resident jax array backend
(rows carry a ``backend`` field; engine-level outcomes are equal to the
numpy rows up to float tolerance — see ``docs/ARCHITECTURE.md``);
``--append`` merges fresh rows into an existing --out file at the
(grid point, policy, backend) key, which is how the per-backend
comparison rows are added without re-running the full grid.

Usage:
    python -m benchmarks.bench_sim                 # full grid (~minutes)
    python -m benchmarks.bench_sim --smoke
    python -m benchmarks.bench_sim --policies pdors,drf --presets philly
    python -m benchmarks.bench_sim --smoke --backend jax \
        --policies pdors --append
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

from repro.core import SubproblemConfig, make_cluster
from repro.obs import Tracer
from repro.sim import (
    FaultPlan,
    ResilientPolicy,
    RollingWindow,
    SimEngine,
    TraceConfig,
    available_policies,
    calibrate_prices,
    make_policy,
    merge_event_streams,
    stream,
)

DEFAULT_POLICIES = ["pdors", "fifo", "drf", "dorm"]
# (H machines, W lookahead, preset, num_jobs, arrival_rate, failure_rate)
FULL_GRID = [
    (8, 16, "google", 500, 4.0, 0.05),
    (16, 16, "google", 500, 6.0, 0.05),
    (8, 16, "philly", 500, 4.0, 0.08),
]
SMOKE_GRID = [(6, 12, "google", 60, 3.0, 0.10)]
# stream tier: one long google stream through the batched engine with
# streaming metrics (the interactive-scale configuration), plus a pdors
# service-latency row through the asyncio OfferService boundary
STREAM_GRID = [(8, 16, "google", 100_000, 4.0, 0.02)]
STREAM_SMOKE_GRID = [(6, 12, "google", 4000, 4.0, 0.02)]
# elastic tier: a reshape storm (most jobs elastic, both the SLAQ shrink
# and adadamp grow triggers armed, deadlines + loss SLOs riding along);
# each row also replays the identical trace through the per-event oracle
# and records batched-vs-event bit-parity (engine_parity)
ELASTIC_GRID = [(8, 16, "google", 300, 4.0, 0.05)]
ELASTIC_SMOKE_GRID = [(6, 12, "google", 60, 3.0, 0.10)]
ELASTIC_KNOBS = dict(
    elastic_frac=0.7, elastic_levels=(0.5, 1.0, 1.5),
    marginal_floor=0.15, damper_loss=0.6,
    deadline_frac=0.5, slo_frac=0.5,
)
SERVICE_JOBS_CAP = 1500
QUANTA = 12
CALIB_JOBS = 48


def _peak_rss_mb() -> Optional[float]:
    """Process peak RSS in MiB (Linux ru_maxrss is KiB); None where the
    resource module is unavailable."""
    try:
        import resource
    except ImportError:  # pragma: no cover - non-posix
        return None
    kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return kb / 1024.0


def chaos_plan(seed: int, H: int, max_slots: int) -> FaultPlan:
    """The benchmark's fault grid: correlated rack crashes + stragglers
    plus injected LP faults (contained by the resilient wrapper)."""
    return FaultPlan(
        seed=seed, until=min(max_slots, 256),
        crash_rate=0.01, straggler_rate=0.01, downtime=(2, 8),
        domains=[(h, h + 1) for h in range(0, H - 1, 2)],
        domain_correlation=0.5,
        solver_fault_rate=0.2,
    )


def run_point(
    H: int,
    W: int,
    preset: str,
    num_jobs: int,
    rate: float,
    failure_rate: float,
    policies: List[str],
    seed: int,
    max_slots: int,
    backend: str = "numpy",
    faults: bool = False,
    profile: bool = False,
) -> List[Dict]:
    tcfg = TraceConfig(
        preset=preset, num_jobs=num_jobs, seed=seed, arrival_rate=rate,
        failure_rate=failure_rate,
    )
    point = {
        "H": H, "W": W, "preset": preset, "num_jobs": num_jobs,
        "arrival_rate": rate, "failure_rate": failure_rate, "seed": seed,
        "quanta": QUANTA, "patience": tcfg.patience, "backend": backend,
        "faults": faults,
    }
    plan = chaos_plan(seed, H, max_slots) if faults else None
    rows = []
    for name in policies:
        cluster = make_cluster(H, W, backend=backend)
        window = RollingWindow(cluster)
        if name.startswith("pdors"):
            params = calibrate_prices(tcfg, cluster, n=CALIB_JOBS)
            if plan is not None:
                # chaos leg: the pdors family runs resilient-wrapped with
                # the plan's injected-solver-fault hook (fresh injector
                # per policy run), so LP faults degrade instead of crash
                policy = ResilientPolicy(
                    inner=name, price_params=params, quanta=QUANTA,
                    cfg=SubproblemConfig(
                        lp_fault_hook=plan.solver_fault_hook()),
                )
            else:
                policy = make_policy(name, price_params=params,
                                     quanta=QUANTA)
        else:
            policy = make_policy(name)
        tracer = Tracer() if profile else None
        engine = SimEngine(
            window, policy, seed=seed, max_slots=max_slots,
            patience=tcfg.patience, trace=tracer,
        )
        events = stream(tcfg)
        if plan is not None:
            events = merge_event_streams(events, plan.events(H))
        t0 = time.perf_counter()
        report = engine.run(events)
        wall = time.perf_counter() - t0
        s = report.summary
        row = {
            **point, "policy": name, "wall_s": wall,
            "jobs_per_sec": num_jobs / wall if wall else float("inf"),
            "slots_run": report.slots_run, **s,
        }
        if tracer is not None:
            row["profile"] = {
                "phases": tracer.phase_table(),
                "coverage": (tracer.total_self_s() / wall) if wall else 0.0,
                "spans": len(tracer.spans),
            }
        if report.pd_gap is not None:
            for k in ("pd_primal", "pd_dual", "duality_gap",
                      "empirical_ratio", "ratio_bound"):
                row[k] = report.pd_gap[k]
        rows.append(row)
        extra = ""
        if faults:
            extra = (f" goodput={s['goodput_fraction']:.2f} "
                     f"mttr={s['mttr']:.1f} "
                     f"avail={s['machine_availability']:.3f}")
        if tracer is not None:
            extra += f" coverage={row['profile']['coverage']:.1%}"
            if "duality_gap" in row:
                extra += f" gap={row['duality_gap']:.2f}"
        print(
            f"  {name:>10}: {num_jobs / wall:8.1f} jobs/s "
            f"done={s['jobs_completed']}/{s['jobs_offered']} "
            f"adm={s['admission_rate']:.2f} pre={s['preemptions']} "
            f"jct p50={s['jct_p50']:.1f} p95={s['jct_p95']:.1f} "
            f"util={s['total_utility']:.1f}" + extra,
            flush=True,
        )
    return rows


def run_stream_point(
    H: int,
    W: int,
    preset: str,
    num_jobs: int,
    rate: float,
    failure_rate: float,
    seed: int,
    policy: str = "fifo",
) -> Dict:
    """Sustained-throughput row: one long stream through the batched
    engine with streaming metrics — the configuration that holds 100k-job
    traces at interactive speed. Records wall-clock jobs/sec, the
    engine's admission-latency quantiles, and process peak RSS."""
    tcfg = TraceConfig(
        preset=preset, num_jobs=num_jobs, seed=seed, arrival_rate=rate,
        failure_rate=failure_rate,
    )
    cluster = make_cluster(H, W)
    window = RollingWindow(cluster)
    if policy.startswith("pdors"):
        params = calibrate_prices(tcfg, cluster, n=CALIB_JOBS)
        pol = make_policy(policy, price_params=params, quanta=QUANTA)
    else:
        pol = make_policy(policy)
    # the stream outlives any fixed slot budget: bound by trace length
    max_slots = int(num_jobs / rate * 4) + 4 * W
    engine = SimEngine(
        window, pol, seed=seed, max_slots=max_slots,
        patience=tcfg.patience, metrics_mode="streaming",
        engine_mode="batched",
    )
    t0 = time.perf_counter()
    report = engine.run(stream(tcfg))
    wall = time.perf_counter() - t0
    s = report.summary
    lat = engine.admission_latency()
    row = {
        "kind": "stream", "H": H, "W": W, "preset": preset,
        "num_jobs": num_jobs, "arrival_rate": rate,
        "failure_rate": failure_rate, "seed": seed, "quanta": QUANTA,
        "backend": "numpy", "faults": False, "policy": policy,
        "engine_mode": "batched", "metrics_mode": "streaming",
        "wall_s": wall,
        "jobs_per_sec": num_jobs / wall if wall else float("inf"),
        "slots_run": report.slots_run,
        "admission_p50_ms": lat["p50_ms"],
        "admission_p99_ms": lat["p99_ms"],
        "admission_mean_ms": lat["mean_ms"],
        "peak_rss_mb": _peak_rss_mb(),
        **s,
    }
    rss = row["peak_rss_mb"]
    rss_txt = f"{rss:.0f}MB" if rss is not None else "n/a"
    print(
        f"  {policy:>10} [stream]: {row['jobs_per_sec']:8.1f} jobs/s "
        f"wall={wall:.1f}s slots={report.slots_run} "
        f"done={s['jobs_completed']}/{s['jobs_offered']} "
        f"adm p99={lat['p99_ms']:.2f}ms rss={rss_txt}",
        flush=True,
    )
    return row


def run_elastic_point(
    H: int,
    W: int,
    preset: str,
    num_jobs: int,
    rate: float,
    failure_rate: float,
    policies: List[str],
    seed: int,
    max_slots: int,
) -> List[Dict]:
    """Elastic-tier rows: a reshape storm replayed per policy through the
    batched engine (throughput + quality columns) AND the per-event
    oracle, recording ``engine_parity`` — bit-identical summary and slot
    count across engine modes on the same elastic trace."""
    tcfg = TraceConfig(
        preset=preset, num_jobs=num_jobs, seed=seed, arrival_rate=rate,
        failure_rate=failure_rate, **ELASTIC_KNOBS,
    )
    rows = []
    for name in policies:
        reports = {}
        for mode in ("batched", "event"):
            cluster = make_cluster(H, W)
            window = RollingWindow(cluster)
            if name.startswith("pdors"):
                params = calibrate_prices(tcfg, cluster, n=CALIB_JOBS)
                policy = make_policy(name, price_params=params,
                                     quanta=QUANTA)
            else:
                policy = make_policy(name)
            engine = SimEngine(
                window, policy, seed=seed, max_slots=max_slots,
                patience=tcfg.patience, engine_mode=mode,
            )
            t0 = time.perf_counter()
            report = engine.run(stream(tcfg))
            reports[mode] = (report, time.perf_counter() - t0)
        rb, wall = reports["batched"]
        re_, _ = reports["event"]
        parity = (rb.summary == re_.summary
                  and rb.slots_run == re_.slots_run)
        s = rb.summary
        rows.append({
            "kind": "elastic", "H": H, "W": W, "preset": preset,
            "num_jobs": num_jobs, "arrival_rate": rate,
            "failure_rate": failure_rate, "seed": seed, "quanta": QUANTA,
            "backend": "numpy", "faults": False, "policy": name,
            "engine_mode": "batched", "engine_parity": parity,
            **{f"elastic_{k}": (list(v) if isinstance(v, tuple) else v)
               for k, v in ELASTIC_KNOBS.items()},
            "wall_s": wall,
            "jobs_per_sec": num_jobs / wall if wall else float("inf"),
            "slots_run": rb.slots_run, **s,
        })
        print(
            f"  {name:>10} [elastic]: {num_jobs / wall:8.1f} jobs/s "
            f"reshapes={s['reshapes']} "
            f"ddl={s['deadline_hits']}/{s['deadline_jobs']} "
            f"slo={s['slo_hits']}/{s['slo_jobs']} "
            f"loss={s['final_loss_mean']:.3f} "
            f"parity={'OK' if parity else 'BROKEN'}",
            flush=True,
        )
    return rows


def run_service_point(
    H: int,
    W: int,
    preset: str,
    num_jobs: int,
    rate: float,
    seed: int,
) -> Dict:
    """Service-latency row: pdors offers through the asyncio
    ``OfferService`` boundary (admission batching + long-poll grant
    queue), measuring sustained offer throughput and the service's
    admission-latency SLO quantiles."""
    import asyncio

    from repro.core.pdors import PDORS
    from repro.sim import OfferService, sample_jobs

    n = min(num_jobs, SERVICE_JOBS_CAP)
    tcfg = TraceConfig(preset=preset, num_jobs=n, seed=seed,
                       arrival_rate=rate)
    jobs = sample_jobs(tcfg, n)
    cluster = make_cluster(H, W)
    params = calibrate_prices(tcfg, cluster, n=CALIB_JOBS)
    sched = PDORS(cluster, params, quanta=QUANTA, seed=seed)

    async def drive():
        svc = await OfferService(sched, batch_window=0.0005).start()
        svc.register("bench-w0", cores=H)
        t0 = time.perf_counter()
        recs = []
        chunk = 64
        for i in range(0, len(jobs), chunk):
            recs.extend(await asyncio.gather(
                *[svc.submit(j) for j in jobs[i:i + chunk]]))
        wall = time.perf_counter() - t0
        grants = 0
        while True:
            more = await svc.poll("bench-w0", timeout=0.01, max_items=256)
            if not more:
                break
            grants += len(more)
        lat = svc.admission_latency()
        batches = svc.batches_total
        await svc.close()
        return recs, wall, lat, grants, batches

    recs, wall, lat, grants, batches = asyncio.run(drive())
    admitted = sum(1 for r in recs if r.admitted)
    row = {
        "kind": "service", "H": H, "W": W, "preset": preset,
        "num_jobs": n, "arrival_rate": rate, "seed": seed,
        "quanta": QUANTA, "backend": "numpy", "faults": False,
        "policy": "pdors", "wall_s": wall,
        "jobs_per_sec": n / wall if wall else float("inf"),
        "jobs_offered": len(recs), "jobs_admitted": admitted,
        "grants_polled": grants, "batches": batches,
        "admission_p50_ms": lat["p50_ms"],
        "admission_p99_ms": lat["p99_ms"],
        "admission_mean_ms": lat["mean_ms"],
        "peak_rss_mb": _peak_rss_mb(),
    }
    print(
        f"  {'pdors':>10} [service]: {row['jobs_per_sec']:8.1f} offers/s "
        f"adm={admitted}/{len(recs)} grants={grants} batches={batches} "
        f"p50={lat['p50_ms']:.2f}ms p99={lat['p99_ms']:.2f}ms",
        flush=True,
    )
    return row


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (< 60 s)")
    ap.add_argument("--stream", action="store_true",
                    help="stream tier: one long google stream through the "
                         "batched engine (streaming metrics, sustained "
                         "jobs/sec + admission-latency quantiles + peak "
                         "RSS) plus a pdors service-latency row through "
                         "the asyncio OfferService boundary")
    ap.add_argument("--smoke-scale", action="store_true",
                    help="CI-sized stream tier (same rows as --stream at "
                         "a scaled-down job count)")
    ap.add_argument("--elastic", action="store_true",
                    help="elastic tier: replay a reshape storm (SLAQ "
                         "shrink + adadamp grow triggers armed, deadlines "
                         "and loss SLOs attached) per policy; rows carry "
                         "kind=elastic, the quality/SLO columns, and an "
                         "engine_parity bool (batched vs per-event oracle "
                         "bit-identity on the same elastic trace)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="override the stream tier's job count (e.g. "
                         "--stream --jobs 100000)")
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES),
                    help=f"comma list from {available_policies()}")
    ap.add_argument("--presets", default=None,
                    help="restrict the grid to these presets (comma list)")
    ap.add_argument("--with-reference", action="store_true",
                    help="also run the frozen scalar core (pdors_ref, slow)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-slots", type=int, default=4000)
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax"],
                    help="array backend for the window ledger "
                         "(see docs/ARCHITECTURE.md)")
    ap.add_argument("--faults", action="store_true",
                    help="chaos leg: merge a correlated machine-fault "
                         "plan into every trace and inject LP solver "
                         "faults (pdors runs resilient-wrapped); rows "
                         "carry faults=true plus goodput/MTTR/"
                         "availability columns")
    ap.add_argument("--append", action="store_true",
                    help="merge rows into an existing --out file instead "
                         "of rewriting it")
    ap.add_argument("--profile", action="store_true",
                    help="run every engine with a repro.obs tracer and "
                         "attach a per-phase wall-time breakdown to each "
                         "row (pdors rows also carry duality-gap and "
                         "empirical-competitive-ratio columns) — see "
                         "docs/OBSERVABILITY.md")
    ap.add_argument("--out", default="BENCH_sim.json")
    args = ap.parse_args(argv)

    if args.stream or args.smoke_scale:
        grid = STREAM_SMOKE_GRID if args.smoke_scale else STREAM_GRID
        all_rows: List[Dict] = []
        for (H, W, preset, n, rate, frate) in grid:
            if args.jobs is not None:
                n = args.jobs
            print(f"# stream H={H} W={W} preset={preset} jobs={n} "
                  f"rate={rate} failures={frate} ...", flush=True)
            t0 = time.time()
            all_rows.append(run_stream_point(
                H, W, preset, n, rate, frate, args.seed))
            all_rows.append(run_service_point(
                H, W, preset, n, rate, args.seed))
            print(f"# point done in {time.time() - t0:.1f}s", flush=True)
        meta = {"quanta": QUANTA, "calib_jobs": CALIB_JOBS}
        if args.append:
            from .bench_scheduler import merge_rows
            doc = merge_rows(
                args.out, all_rows, meta,
                key_fields=("kind", "H", "W", "preset", "num_jobs",
                            "arrival_rate", "seed", "policy"),
            )
        else:
            doc = dict(meta, rows=all_rows)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {args.out} ({len(all_rows)} fresh rows, "
              f"{len(doc['rows'])} total)")
        return 0

    if args.elastic:
        grid = ELASTIC_SMOKE_GRID if args.smoke else ELASTIC_GRID
        policies = [p for p in args.policies.split(",") if p]
        for p in policies:
            if p not in available_policies():
                ap.error(f"unknown policy {p!r}; available: "
                         f"{available_policies()}")
        all_rows = []
        for (H, W, preset, n, rate, frate) in grid:
            print(f"# elastic H={H} W={W} preset={preset} jobs={n} "
                  f"rate={rate} failures={frate} ...", flush=True)
            t0 = time.time()
            all_rows.extend(run_elastic_point(
                H, W, preset, n, rate, frate, policies, args.seed,
                args.max_slots))
            print(f"# point done in {time.time() - t0:.1f}s", flush=True)
        meta = {"quanta": QUANTA, "calib_jobs": CALIB_JOBS}
        if args.append:
            from .bench_scheduler import merge_rows
            doc = merge_rows(
                args.out, all_rows, meta,
                key_fields=("kind", "H", "W", "preset", "num_jobs",
                            "arrival_rate", "failure_rate", "seed",
                            "policy"),
            )
        else:
            doc = dict(meta, rows=all_rows)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {args.out} ({len(all_rows)} fresh rows, "
              f"{len(doc['rows'])} total)")
        return 0

    grid = SMOKE_GRID if args.smoke else FULL_GRID
    if args.presets:
        keep = set(args.presets.split(","))
        grid = [g for g in grid if g[2] in keep]
    policies = [p for p in args.policies.split(",") if p]
    if args.with_reference and "pdors_ref" not in policies:
        policies.append("pdors_ref")
    for p in policies:
        if p not in available_policies():
            ap.error(f"unknown policy {p!r}; available: {available_policies()}")

    all_rows: List[Dict] = []
    for (H, W, preset, n, rate, frate) in grid:
        print(f"# sim H={H} W={W} preset={preset} jobs={n} rate={rate} "
              f"failures={frate} ...", flush=True)
        t0 = time.time()
        all_rows.extend(
            run_point(H, W, preset, n, rate, frate, policies, args.seed,
                      args.max_slots, backend=args.backend,
                      faults=args.faults, profile=args.profile)
        )
        print(f"# point done in {time.time() - t0:.1f}s", flush=True)

    meta = {"quanta": QUANTA, "calib_jobs": CALIB_JOBS}
    if args.append:
        from .bench_scheduler import merge_rows
        doc = merge_rows(
            args.out, all_rows, meta,
            key_fields=("H", "W", "preset", "num_jobs", "arrival_rate",
                        "failure_rate", "seed", "policy", "faults"),
        )
    else:
        doc = dict(meta, rows=all_rows)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {args.out} ({len(all_rows)} fresh rows, "
          f"{len(doc['rows'])} total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
