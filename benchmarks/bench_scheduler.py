"""Scheduler-throughput benchmark: vectorized PD-ORS vs the frozen pre-PR
core vs the §5 baselines, over an (H, T, num_jobs) grid.

For every grid point and policy we measure wall-clock jobs/sec and the
p50/p95 per-``offer()`` latency (per-slot step latency for the slot-driven
baselines), plus total utility and admissions so the numbers stay tied to
scheduling quality. The PD-ORS rows additionally record the speedup of the
vectorized core over the pre-PR reference and assert bit-identical
admission decisions + total utility at the shared seed (the perf claim is
only meaningful if the answer is unchanged).

Workload regime: the default grid runs the online many-small-jobs mix
(``workload_scale=0.003`` — jobs sized so a single machine can host them,
the regime where an *online* scheduler's own latency is the bottleneck and
the ROADMAP's heavy-traffic goal lives). The DP granularity is the library
default ``quanta=32``. A heavy-contention point (``workload_scale=0.3``,
jobs needing 100+ workers spread across machines, every theta solving the
cover/packing LP) is included so the smaller speedup of the LP-bound
regime is reported honestly alongside.

Output: ``BENCH_scheduler.json`` (or --out) with one record per
(grid point, policy, backend). ``--backend jax`` runs the PD-ORS rows on
the device-resident jax array backend (see ``docs/ARCHITECTURE.md``);
against the frozen reference those rows are tolerance-parity, so the
decision-identity gate only applies to the numpy backend. ``--append``
merges fresh rows into an existing --out file (replacing rows at the
same grid/policy/backend key) instead of rewriting it — how the
per-backend comparison rows are added without re-running the full grid.

Usage:
    python -m benchmarks.bench_scheduler            # full grid (~tens of min)
    python -m benchmarks.bench_scheduler --smoke    # tiny grid, < 60 s
    python -m benchmarks.bench_scheduler --points 50x40x100 --no-reference
    python -m benchmarks.bench_scheduler --backend jax --points 25x20x50 \
        --workload-scale 0.3 --baselines "" --append
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from contextlib import nullcontext
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    PDORS,
    WorkloadConfig,
    estimate_price_params,
    make_cluster,
    run_baseline,
    synthetic_jobs,
)
from repro.core._reference import PDORSReference, make_cluster_reference
from repro.obs import Tracer
from repro.obs import trace as obs_trace

# (H, T, jobs, workload_scale); acceptance point 50x40x100 runs last so
# partial runs still produce the smaller rows first
ONLINE_SCALE = 0.003   # many-small-jobs online mix (see module docstring)
HEAVY_SCALE = 0.3      # LP-bound contention mix
FULL_GRID = [
    (10, 10, 20, ONLINE_SCALE),
    (25, 20, 50, ONLINE_SCALE),
    (25, 20, 50, HEAVY_SCALE),
    (50, 40, 100, ONLINE_SCALE),
]
# smoke: one online point + two heavy-contention points, so CI exercises
# (and bench_guard gates) BOTH regimes. The tiny heavy point covers the
# LP-bound code path cheaply; the FULL heavy point (25x20x50) is where
# the structure-aware solver's speedup is large and stable enough to
# gate (`--min-speedup-point` in bench_guard) — at small scale the
# per-offer fixed costs dominate and the ratio is noise (see
# docs/BENCHMARKS.md). Run last so partial runs keep the cheap rows.
SMOKE_GRID = [(6, 8, 10, ONLINE_SCALE), (6, 8, 10, HEAVY_SCALE),
              (25, 20, 50, HEAVY_SCALE)]
BENCH_BATCH = (50, 200)
QUANTA = 32  # DP workload granularity: the run_pdors default


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.array(xs), q)) if xs else 0.0


def _decisions(records) -> List[tuple]:
    out = []
    for r in records:
        slots = None
        if r.schedule is not None:
            slots = tuple(
                (t, tuple(sorted(a.workers.items())), tuple(sorted(a.ps.items())))
                for t, a in sorted(r.schedule.slots.items())
            )
        out.append((r.job.job_id, r.admitted, r.utility, slots))
    return out


def _run_pdors_timed(jobs, cluster_factory, scheduler_cls, seed: int,
                     repeat_best_of: int = 1, profile: bool = False) -> Dict:
    """Time one scheduler run; with ``repeat_best_of > 1`` repeat the
    whole run on a FRESH cluster each time and report the best wall
    clock (latencies from the best run).  Decisions are deterministic at
    a fixed seed, so every rep produces the same records — the repeats
    only filter out scheduling noise from shared benchmark boxes (see
    docs/BENCHMARKS.md, "noisy-box vs quiet-run methodology").

    ``profile=True`` activates a fresh ``repro.obs`` tracer around each
    rep's offer loop (decisions are unaffected — spans never touch rng
    or decision state) and attaches the per-phase breakdown, coverage
    (traced root time / measured wall), and — for the vectorized core —
    the primal-dual telemetry snapshot to the row."""
    best: Optional[Dict] = None
    ordered = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    for _ in range(max(1, repeat_best_of)):
        cluster = cluster_factory()
        params = estimate_price_params(jobs, cluster, cluster.horizon)
        sched = scheduler_cls(cluster, params, quanta=QUANTA, seed=seed)
        tracer = Tracer() if profile else None
        lat: List[float] = []
        with (obs_trace.activate(tracer) if tracer is not None
              else nullcontext()):
            t0 = time.perf_counter()
            for job in ordered:
                t1 = time.perf_counter()
                sched.offer(job)
                lat.append(time.perf_counter() - t1)
            wall = time.perf_counter() - t0
        records = sched.records
        out = {
            "wall_s": wall,
            "jobs_per_sec": len(jobs) / wall if wall else float("inf"),
            "latency_p50_ms": _pct(lat, 50) * 1e3,
            "latency_p95_ms": _pct(lat, 95) * 1e3,
            "utility": float(sum(r.utility for r in records)),
            "admitted": sum(1 for r in records if r.admitted),
            "decisions": _decisions(records),
        }
        if tracer is not None:
            out["profile"] = {
                "phases": tracer.phase_table(),
                "coverage": (tracer.total_self_s() / wall) if wall else 0.0,
                "spans": len(tracer.spans),
            }
            gap = getattr(sched, "pd_gap", None)
            if gap is not None:
                snap = gap.snapshot()
                for k in ("pd_primal", "pd_dual", "duality_gap",
                          "empirical_ratio", "ratio_bound"):
                    out[k] = snap[k]
        if best is None or out["wall_s"] < best["wall_s"]:
            best = out
    return best


def _run_baseline_timed(name: str, jobs, cluster, seed: int) -> Dict:
    t0 = time.perf_counter()
    out = run_baseline(name, jobs, cluster, seed=seed)
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "jobs_per_sec": len(jobs) / wall if wall else float("inf"),
        # slot-driven baselines have no per-job offer; report per-slot cost
        "latency_p50_ms": wall / max(cluster.horizon, 1) * 1e3,
        "latency_p95_ms": wall / max(cluster.horizon, 1) * 1e3,
        "utility": float(out.total_utility),
        "admitted": len(out.completions),
    }


def bench_point(H: int, T: int, num_jobs: int, scale: float, seed: int,
                with_reference: bool, baselines: List[str],
                backend: str = "numpy", repeat_best_of: int = 1,
                profile: bool = False) -> List[Dict]:
    cfg = WorkloadConfig(num_jobs=num_jobs, horizon=T, seed=seed,
                         batch=BENCH_BATCH, workload_scale=scale)
    jobs = synthetic_jobs(cfg)
    point = {"H": H, "T": T, "num_jobs": num_jobs, "seed": seed,
             "workload_scale": scale, "quanta": QUANTA, "backend": backend}
    # only the pdors/pdors_reference measurements repeat; the slot-driven
    # baselines are timed single-shot, so the field is stamped per row
    bo = {"repeat_best_of": repeat_best_of}
    rows: List[Dict] = []

    vec = _run_pdors_timed(
        jobs, lambda: make_cluster(H, T, backend=backend), PDORS, seed,
        repeat_best_of, profile=profile,
    )
    vec_decisions = vec.pop("decisions")
    rows.append({**point, "policy": "pdors", **bo, **vec})

    if with_reference:
        # the frozen scalar core is host-only: reference rows are always
        # backend "numpy"; against a jax pdors row the identity flag is
        # informational (the jax backend's contract is tolerance parity)
        ref = _run_pdors_timed(
            jobs, lambda: make_cluster_reference(H, T), PDORSReference,
            seed, repeat_best_of,
        )
        ref_decisions = ref.pop("decisions")
        identical = (
            vec_decisions == ref_decisions
            and rows[-1]["utility"] == ref["utility"]
        )
        speedup = ref["wall_s"] / vec["wall_s"] if vec["wall_s"] else 0.0
        rows[-1]["speedup_vs_reference"] = speedup
        rows[-1]["decisions_identical_to_reference"] = identical
        if backend == "numpy":
            # the reference row is only (re)recorded alongside a numpy
            # pdors row: a jax --append run re-timing it would replace the
            # row the numpy sibling's speedup_vs_reference was computed
            # against, leaving the merged file internally inconsistent
            # (the jax pdors row keeps its own self-contained speedup
            # field from this run's fresh reference timing)
            rows.append({**point, "policy": "pdors_reference",
                         "backend": "numpy", **bo, **ref,
                         "speedup_vs_reference": 1.0})
        if not identical:
            print(f"!! decision divergence at H={H} T={T} N={num_jobs} "
                  f"seed={seed} backend={backend}", file=sys.stderr)

    for name in baselines:
        # baselines run on the host scheduler regardless of --backend or
        # the REPRO_BACKEND env var (they never touch the price/ledger
        # tensors), so the cluster is pinned to numpy to match the label —
        # same convention as pdors_reference
        rows.append({
            **point, "policy": name, "backend": "numpy",
            **_run_baseline_timed(
                name, jobs, make_cluster(H, T, backend="numpy"), seed
            ),
        })
    return rows


SCHED_KEY_FIELDS = ("H", "T", "num_jobs", "workload_scale", "seed",
                    "policy")


def merge_rows(path: str, fresh: List[Dict], meta: Dict,
               key_fields=SCHED_KEY_FIELDS) -> Dict:
    """--append: replace same-key rows of an existing bench file, keep the
    rest, and add anything new. The key is ``key_fields`` + backend
    (rows written before the backend axis existed mean numpy; rows
    written before the faults axis existed mean clean traces)."""
    def key(r):
        return tuple(
            bool(r.get(f)) if f == "faults" else r.get(f)
            for f in key_fields
        ) + (r.get("backend") or "numpy",)

    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        doc = dict(meta, rows=[])
    fresh_keys = {key(r) for r in fresh}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if key(r) not in fresh_keys] + fresh
    return doc


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (<60 s) for CI")
    ap.add_argument("--points", default=None,
                    help="comma-separated HxTxJOBS triples, e.g. 50x40x100")
    ap.add_argument("--workload-scale", type=float, default=None,
                    help="override workload_scale for --points entries")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-reference", action="store_true",
                    help="skip the slow pre-PR core measurement")
    ap.add_argument("--baselines", default="fifo,drf,dorm",
                    help="comma-separated baseline list (may be empty)")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax"],
                    help="array backend for the pdors rows "
                         "(see docs/ARCHITECTURE.md)")
    ap.add_argument("--append", action="store_true",
                    help="merge rows into an existing --out file instead "
                         "of rewriting it")
    ap.add_argument("--repeat-best-of", type=int, default=1,
                    help="run each timed measurement N times on a fresh "
                         "cluster and keep the best wall — the quiet-run "
                         "hint for shared boxes (decisions are "
                         "deterministic, so only timing changes; see "
                         "docs/BENCHMARKS.md)")
    ap.add_argument("--profile", action="store_true",
                    help="trace the pdors offer loop with the repro.obs "
                         "tracer and attach a per-phase wall-time "
                         "breakdown plus primal-dual telemetry "
                         "(duality gap, empirical competitive ratio) to "
                         "each pdors row — see docs/OBSERVABILITY.md")
    ap.add_argument("--out", default="BENCH_scheduler.json")
    args = ap.parse_args(argv)

    if args.points:
        scale = (args.workload_scale if args.workload_scale is not None
                 else ONLINE_SCALE)
        try:
            grid = [tuple(int(v) for v in p.split("x")) + (scale,)
                    for p in args.points.split(",")]
            if any(len(g) != 4 for g in grid):
                raise ValueError
        except ValueError:
            ap.error(f"--points must be HxTxJOBS triples, got {args.points!r}")
    else:
        grid = SMOKE_GRID if args.smoke else FULL_GRID
    baselines = [b for b in args.baselines.split(",") if b]

    all_rows: List[Dict] = []
    ok = True
    for (H, T, N, scale) in grid:
        print(f"# bench H={H} T={T} jobs={N} scale={scale} ...", flush=True)
        t0 = time.time()
        rows = bench_point(H, T, N, scale, args.seed,
                           with_reference=not args.no_reference,
                           baselines=baselines, backend=args.backend,
                           repeat_best_of=args.repeat_best_of,
                           profile=args.profile)
        for r in rows:
            extra = ""
            if "speedup_vs_reference" in r and r["policy"] == "pdors":
                extra = (f" speedup={r['speedup_vs_reference']:.1f}x"
                         f" identical={r['decisions_identical_to_reference']}")
                if args.backend == "numpy":   # jax rows: tolerance parity
                    ok &= bool(r["decisions_identical_to_reference"])
            if "profile" in r:
                extra += (f" coverage={r['profile']['coverage']:.1%}"
                          f" gap={r.get('duality_gap', float('nan')):.2f}"
                          f" ratio={r.get('empirical_ratio') or float('nan'):.3f}")
            print(f"  {r['policy']:>16}: {r['jobs_per_sec']:8.2f} jobs/s "
                  f"p50={r['latency_p50_ms']:8.2f}ms "
                  f"p95={r['latency_p95_ms']:8.2f}ms "
                  f"util={r['utility']:.1f} adm={r['admitted']}{extra}",
                  flush=True)
        all_rows.extend(rows)
        print(f"# point done in {time.time()-t0:.1f}s", flush=True)

    meta = {"batch": list(BENCH_BATCH), "quanta": QUANTA}
    doc = (merge_rows(args.out, all_rows, meta) if args.append
           else dict(meta, rows=all_rows))
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {args.out} ({len(all_rows)} fresh rows, "
          f"{len(doc['rows'])} total)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
