"""Fig. 10: empirical competitive ratio = OPT(offline) / PD-ORS on tiny
instances solved exactly by brute force.  Paper reports ratios in [1.0, 1.4]
for I<=10, T<=10; our exact search uses I<=5, T<=6, H<=2 (DESIGN.md §9)."""
import time

import numpy as np

from repro.core import (
    JobSpec,
    SigmoidUtility,
    make_cluster,
    offline_optimum,
    run_pdors,
)


def tiny_jobs(num: int, seed: int):
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(num):
        F = int(rng.integers(3, 7))
        jobs.append(JobSpec(
            job_id=i,
            arrival=int(rng.integers(0, 3)),
            epochs=1,
            num_samples=int(rng.integers(2_500, 6_000)),
            batch_size=F,
            tau=1e-3,
            grad_size=100.0,
            gamma=float(rng.uniform(1.5, 3.0)),
            bw_internal=1e6,
            bw_external=2e5,
            worker_demand={"gpu": 1.0, "cpu": 2.0, "mem": 4.0, "storage": 1.0},
            ps_demand={"gpu": 0.0, "cpu": 2.0, "mem": 4.0, "storage": 1.0},
            utility=SigmoidUtility(float(rng.uniform(20, 60)),
                                   float(rng.uniform(0.3, 1.0)),
                                   float(rng.uniform(2, 4))),
        ))
    return jobs


def run(full: bool = False):
    ratios = []
    n_seeds = 6 if full else 4
    for seed in range(n_seeds):
        for I in (3, 4, 5):
            jobs = tiny_jobs(I, seed)
            T, H = 5, 2
            # tight capacity (~10 workers/machine) so jobs contend — the
            # paper's ratios (1.0-1.4) arise from contention
            t0 = time.time()
            opt = offline_optimum(jobs, make_cluster(H, T, capacity_scale=0.1))
            res = run_pdors(jobs, make_cluster(H, T, capacity_scale=0.1),
                            quanta=T, seed=seed)
            wall = time.time() - t0
            if res.total_utility > 1e-9:
                # PD-ORS's own solution is feasible offline, so true OPT >=
                # max(search result, PD-ORS) — keeps the ratio valid (>= 1)
                opt_util = max(opt.total_utility, res.total_utility)
                ratio = opt_util / res.total_utility
                ratios.append(ratio)
                print(f"fig10_competitive[I={I},seed={seed}],"
                      f"{wall / max(len(jobs),1) * 1e6:.0f},"
                      f"ratio={ratio:.3f}")
    if ratios:
        print(f"fig10_summary,0,mean={np.mean(ratios):.3f};"
              f"max={np.max(ratios):.3f};min={np.min(ratios):.3f}")
        # paper remark ii: the Theorem-5 worst-case bound is far more
        # conservative than the measured ratio
        from repro.core import theorem5_bound

        jobs = tiny_jobs(5, 0)
        bound = theorem5_bound(jobs, make_cluster(2, 5, capacity_scale=0.1),
                               5, delta=0.5)
        print(f"fig10_theory,0,thm5_bound={bound.ratio:.1f};"
              f"empirical_max={np.max(ratios):.3f};"
              f"slack={bound.ratio / max(np.max(ratios), 1e-9):.0f}x")
    return ratios


if __name__ == "__main__":
    run()
