"""§Perf hillclimb driver + report (deliverable g).

Three pairs (chosen per the spec: worst roofline fraction, most
collective-bound, most paper-representative) iterated with explicit
hypothesis -> change -> measure -> verdict cycles.  Running this module
re-measures every variant (slow: ~40 min of CPU compiles); results are
archived in results/perf_iterations.json and summarized in
EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.perf_iterations [--pairs 1,2,3]
"""
import argparse
import dataclasses
import json
import os
import sys


def _run_all(pairs):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))
    import jax  # noqa: F401  (device count must be set before first use)

    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import dryrun_one
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import roofline_terms

    out = {}

    def measure(tag, arch, shape, cfg=None, **kw):
        cfgo = cfg or get_config(arch)
        r = dryrun_one(arch, shape, cfg_override=cfgo, verbose=False, **kw)
        t = roofline_terms(cfgo, SHAPES[shape], r)
        row = {k: t[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "dominant", "useful_flops_frac")}
        row["cross_pod_bytes"] = r["collective_bytes"].get("cross_pod", 0.0)
        out[tag] = row
        print(f"perf[{tag}],0," + ";".join(
            f"{k}={v:.3e}" if isinstance(v, float) else f"{k}={v}"
            for k, v in row.items()))
        return row

    if 1 in pairs:
        # ---- pair 1: minicpm3-4b x train_4k (worst useful fraction) ----
        arch = "minicpm3-4b"
        measure("p1_baseline", arch, "train_4k")
        mesh = make_production_mesh(model_split=2)
        measure("p1_it1_mesh_refactor", arch, "train_4k", mesh_override=mesh)
        cfg = dataclasses.replace(get_config(arch), remat="dots")
        measure("p1_it2_remat_dots", arch, "train_4k", cfg=cfg,
                mesh_override=mesh)

    if 2 in pairs:
        # ---- pair 2: mamba2-780m x prefill_32k (most collective-bound) --
        arch = "mamba2-780m"
        measure("p2_baseline", arch, "prefill_32k")
        cfg = dataclasses.replace(get_config(arch), ssm_split_in_proj=True)
        measure("p2_it1_split_in_proj", arch, "prefill_32k", cfg=cfg)

    if 3 in pairs:
        # ---- pair 3: command-r-plus-104b x train_4k (paper-representative:
        # locality-aware placement; multi-pod internal-vs-external sync) ----
        arch = "command-r-plus-104b"
        # paper-faithful baseline: gather-CE, no activation constraints,
        # Megatron-TP + FSDP rules (the state before any iteration)
        cfg_b = dataclasses.replace(get_config(arch), ce_impl="gather")
        measure("p3_it0_baseline", arch, "train_4k", cfg=cfg_b,
                act_constraint=False)
        measure("p3_it1_onehot_ce", arch, "train_4k", act_constraint=False)
        measure("p3_it2_act_constraint", arch, "train_4k")
        measure("p3_it3_pure_fsdp", arch, "train_4k", pure_fsdp=True)
        cfg = dataclasses.replace(get_config(arch), remat="dots")
        measure("p3_it4_fsdp_dots", arch, "train_4k", cfg=cfg,
                pure_fsdp=True)
        measure("p3_multi_A_pod_replicated", arch, "train_4k", multi_pod=True)
        measure("p3_multi_B_fsdp_over_pod", arch, "train_4k", multi_pod=True,
                fsdp_over_pod=True)
        measure("p3_multi_D_tp_over_pod", arch, "train_4k", multi_pod=True,
                tp_over_pod=True)
        measure("p3_multi_A_fsdp_dots", arch, "train_4k", cfg=cfg,
                multi_pod=True)

    if 4 in pairs:
        # ---- beyond the three pairs: MoE expert-layout hillclimb ----
        from repro.parallel import MeshRules  # noqa: F401

        arch = "deepseek-v2-236b"

        def measure_layout(tag, layout, **kw):
            import repro.launch.dryrun as dr
            from repro.parallel import sharding as shmod

            orig_init = shmod.MeshRules.__init__

            def patched(self, *a, **k):
                orig_init(self, *a, **k)
                self.moe_experts_on = layout

            shmod.MeshRules.__init__ = patched
            try:
                return measure(tag, arch, "train_4k", **kw)
            finally:
                shmod.MeshRules.__init__ = orig_init

        measure_layout("p4_ds_train_experts_on_data", "data")
        measure_layout("p4_ds_train_experts_on_data_fsdp", "data",
                       pure_fsdp=True)
        measure("p4_ds_train_experts_on_model", arch, "train_4k")
        measure("p4_ds_decode_experts_on_model", arch, "decode_32k")

    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", default="1,2,3,4")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "perf_iterations.json"))
    args = ap.parse_args()
    pairs = [int(x) for x in args.pairs.split(",")]
    out = _run_all(pairs)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=str)


if __name__ == "__main__":
    main()
