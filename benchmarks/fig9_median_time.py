"""Fig. 9: median actual training time per policy (unfinished jobs count
as T — the paper's convention).  Paper: T=80, H=30, I=100; scaled here."""
import time

import numpy as np

from .common import make_jobs, run_policy


def run(full: bool = False):
    T = 80 if full else 30
    H = 30 if full else 12
    I = 100 if full else 20
    for pol in ("pdors", "oasis", "fifo", "drf", "dorm"):
        meds, uspj = [], []
        for seed in (0, 1):
            # lighter jobs so most policies can finish a majority within T
            jobs = make_jobs(I, T, seed, workload_scale=0.12)
            r = run_policy(pol, jobs, H, T, seed=seed)
            meds.append(float(np.median(r["times"])))
            uspj.append(r["us_per_job"])
        print(f"fig9_median_time[{pol}],{np.mean(uspj):.0f},"
              f"median_slots={np.mean(meds):.1f}")


if __name__ == "__main__":
    run()
