"""Quickstart: the paper's scheduler in 40 lines.

Jobs arrive online; PD-ORS prices resources (Eq. 12), searches schedules
(Algorithms 2-4) and admits profitable jobs.  Compare against FIFO.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    WorkloadConfig,
    make_cluster,
    run_baseline,
    run_pdors,
    synthetic_jobs,
)


def main() -> None:
    # 20 ML training jobs arriving over 15 time-slots, 8 machines
    cfg = WorkloadConfig(num_jobs=20, horizon=15, seed=0,
                         batch=(50, 200), workload_scale=0.2)
    jobs = synthetic_jobs(cfg)

    res = run_pdors(jobs, make_cluster(8, 15), quanta=15)
    print(f"PD-ORS : utility={res.total_utility:8.1f}  "
          f"admitted={len(res.admitted)}/{len(jobs)}")
    for rec in res.admitted[:5]:
        s = rec.schedule
        modes = sorted(set(s.modes.values()))
        print(f"   job {rec.job.job_id:2d}: arrival={rec.job.arrival:2d} "
              f"completion={s.completion:2d} payoff={s.payoff:7.1f} "
              f"locality={'/'.join(modes)}")

    fifo = run_baseline("fifo", jobs, make_cluster(8, 15))
    print(f"FIFO   : utility={fifo.total_utility:8.1f}  "
          f"finished={len(fifo.completions)}/{len(jobs)}")


if __name__ == "__main__":
    main()
