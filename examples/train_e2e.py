"""End-to-end training driver: train a reduced-config model for a few
hundred steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_e2e.py --arch qwen3-32b --steps 200
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    shape = InputShape("e2e", args.seq_len, args.batch, "train")
    trainer = Trainer(cfg, shape, TrainerConfig(
        steps=args.steps, log_every=max(args.steps // 20, 1),
        checkpoint_dir=args.ckpt_dir,
        opt=AdamWConfig(lr=args.lr, weight_decay=0.01)))
    print(f"training {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}) for {args.steps} steps ...")
    hist = trainer.run()
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}  {h['wall']:.1f}s")
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
