"""Batched serving demo: the serving engine over a reduced Gemma config —
prefill + lock-step decode with KV caches (the ``decode`` shapes' runtime).

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main() -> None:
    cfg = get_config("gemma-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=4, cache_len=128)

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                max_new_tokens=12, temperature=0.0)
        for i in range(8)
    ]
    t0 = time.time()
    done = engine.serve(reqs)
    wall = time.time() - t0
    for c in sorted(done, key=lambda c: c.request_id):
        print(f"req {c.request_id}: prefill={c.prefill_ms:6.1f}ms "
              f"decode={c.decode_ms:6.1f}ms tokens={c.tokens[:8]}...")
    n_tok = sum(len(c.tokens) for c in done)
    print(f"\nserved {len(done)} requests, {n_tok} tokens "
          f"in {wall:.2f}s ({n_tok / wall:.1f} tok/s on 1 CPU core)")


if __name__ == "__main__":
    main()
