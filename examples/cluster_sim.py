"""End-to-end driver (deliverable b): PD-ORS schedules DNN training jobs
drawn from the 10 assigned architectures, and each admitted job actually
RUNS as JAX training on its scheduled worker allocation.

The scheduler decides worker counts per slot; the runtime executes a
reduced-config variant of the job's architecture with the data-parallel
batch split implied by the allocation, for a few steps per slot.  This is
the paper's system realized end-to-end: online admission -> placement ->
real SGD training -> completion accounting.

    PYTHONPATH=src python examples/cluster_sim.py [--slots 8] [--jobs 6]

With ``--sim``, the script instead drives the event-driven rolling-horizon
simulator (repro.sim): a Google-trace-like stream with completions,
failures/preemption, and patience departures is replayed through PD-ORS
and the fifo/drf/dorm baselines via the unified policy registry, and the
per-policy JCT/utilization/utility summaries are printed side by side.

    PYTHONPATH=src python examples/cluster_sim.py --sim [--jobs 80]
"""
import argparse
import time


def run_event_sim(args) -> None:
    from repro.core import make_cluster
    from repro.sim import (RollingWindow, SimEngine, TraceConfig,
                           calibrate_prices, make_policy, stream)

    tcfg = TraceConfig(preset="google", num_jobs=args.jobs, seed=args.seed,
                       arrival_rate=3.0, failure_rate=0.1)
    print(f"[sim] replaying {args.jobs} google-trace jobs through "
          f"{args.policies} (window={args.window}, H={args.machines})")
    for name in args.policies.split(","):
        cluster = make_cluster(args.machines, args.window,
                               backend=args.backend)
        window = RollingWindow(cluster)
        if name.startswith("pdors"):
            params = calibrate_prices(tcfg, cluster, n=32)
            policy = make_policy(name, price_params=params, quanta=12)
        else:
            policy = make_policy(name)
        engine = SimEngine(window, policy, seed=args.seed, max_slots=2000,
                           patience=tcfg.patience)
        t0 = time.time()
        s = engine.run(stream(tcfg)).summary
        gpu_util = s["utilization_busy_mean"].get("gpu", 0.0)
        print(f"[sim] {name:>6}: completed {s['jobs_completed']}/"
              f"{s['jobs_offered']} adm={s['admission_rate']:.2f} "
              f"preempt={s['preemptions']} jct p50/p95="
              f"{s['jct_p50']:.1f}/{s['jct_p95']:.1f} "
              f"gpu_util={gpu_util:.2f} utility={s['total_utility']:.1f} "
              f"({time.time() - t0:.1f}s)")
    print("[sim] done")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--steps-per-slot", type=int, default=3)
    ap.add_argument("--sim", action="store_true",
                    help="run the event-driven rolling-horizon simulator "
                         "instead of the static schedule+train demo")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--machines", type=int, default=6)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--policies", default="pdors,fifo,drf,dorm")
    ap.add_argument("--backend", default=None,
                    help="ledger array backend for --sim: numpy | jax "
                         "(default: REPRO_BACKEND env or numpy)")
    args = ap.parse_args()

    if args.sim:
        run_event_sim(args)
        return

    # JAX + model imports deferred so --sim stays lightweight
    import jax

    from repro.configs import ARCH_IDS, get_config
    from repro.configs.base import InputShape
    from repro.core import arch_jobs, make_cluster, run_pdors
    from repro.models import build_model, concrete_batch
    from repro.optim import AdamWConfig
    from repro.train import make_train_state, make_train_step

    # ---- 1. scheduler: admit + place arch-derived jobs --------------------
    stats = {}
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        stats[aid] = {
            "flops_per_token": 2.0 * cfg.active_param_count(),
            "param_bytes": cfg.param_count() * 2.0,
            "seq_len": 512.0,   # fine-tuning-length sequences
        }
    jobs = arch_jobs(stats, num_jobs=args.jobs, horizon=args.slots, seed=0,
                     samples_range=(60, 300), epochs_range=(1, 2))
    cluster = make_cluster(8, args.slots, preset="tpu", capacity_scale=4.0)
    res = run_pdors(jobs, cluster, quanta=args.slots)
    print(f"[scheduler] admitted {len(res.admitted)}/{len(jobs)} jobs, "
          f"total utility {res.total_utility:.1f}")

    # ---- 2. runtime: execute admitted jobs slot by slot --------------------
    runtimes = {}
    for rec in res.admitted:
        aid = rec.job.arch
        cfg = get_config(aid, reduced=True)
        model = build_model(cfg)
        opt = AdamWConfig(lr=1e-3)
        state = make_train_state(model, jax.random.PRNGKey(rec.job.job_id), opt)
        step_fn = jax.jit(make_train_step(model, opt))
        runtimes[rec.job.job_id] = {"cfg": cfg, "model": model, "opt": opt,
                                    "state": state, "step": step_fn,
                                    "losses": []}

    for t in range(args.slots):
        active = [r for r in res.admitted if t in r.schedule.slots]
        if not active:
            continue
        print(f"[slot {t}] running {len(active)} jobs")
        for rec in active:
            alloc = rec.schedule.slots[t]
            n_workers = alloc.total_workers()
            rt = runtimes[rec.job.job_id]
            # data-parallel degree = scheduled workers; global batch fixed
            # (the paper's consistent-batch requirement): per-worker batch
            # shrinks as workers grow
            global_batch = max(4, min(16, n_workers))
            shape = InputShape("sim", 64, global_batch, "train")
            for k in range(args.steps_per_slot):
                # concrete_batch handles every modality (frames for
                # enc-dec, image embeds for VLM, tokens otherwise)
                batch = concrete_batch(rt["cfg"], shape,
                                       seed=rec.job.job_id * 1000 + t * 10 + k)
                rt["state"], metrics = rt["step"](rt["state"], batch)
            rt["losses"].append(float(metrics["loss"]))
            print(f"    job {rec.job.job_id} ({rec.job.arch}): "
                  f"workers={n_workers} loss={rt['losses'][-1]:.3f}")

    print("\n[summary]")
    for rec in res.admitted:
        losses = runtimes[rec.job.job_id]["losses"]
        if len(losses) >= 2:
            print(f"  job {rec.job.job_id} ({rec.job.arch}): "
                  f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
                  f"{len(losses)} scheduled slots")


if __name__ == "__main__":
    main()
